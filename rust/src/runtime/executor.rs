//! Model driver: full LLM forward (embed → layers → head) over the AOT
//! artifacts, with every weight tensor decompressed just-in-time from its
//! ECF8 blob (§3.3). This is the request-path compute the coordinator
//! calls into.

use super::pjrt::{Artifact, Input, PjrtRuntime};
use crate::model::config::ModelConfig;
use crate::model::store::CompressedModel;
use crate::tensormgr::JitDecompressor;
use crate::util::threadpool::ThreadPool;
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;

/// Sequence length the artifacts were lowered with (aot.py SEQ_LEN).
pub const SEQ_LEN: usize = 32;

/// Maps a zoo config name to its artifact prefix.
pub fn artifact_prefix(model_name: &str) -> Option<&'static str> {
    match model_name {
        "pico-llm-125m" => Some("pico_llm"),
        "tiny-llm-7m" => Some("tiny_llm"),
        "pico-dit-50m" => Some("pico_dit"),
        _ => None,
    }
}

/// Executes a compressed LLM through PJRT, decoding weights per layer.
pub struct LlmExecutor {
    rt: PjrtRuntime,
    pub cfg: ModelConfig,
    pub model: CompressedModel,
    jit: JitDecompressor,
    prefix: &'static str,
    /// forward counters
    pub forwards: u64,
}

impl LlmExecutor {
    pub fn new(
        cfg: ModelConfig,
        model: CompressedModel,
        artifacts_dir: std::path::PathBuf,
        pool: Option<Arc<ThreadPool>>,
    ) -> Result<Self> {
        let prefix = artifact_prefix(cfg.name)
            .ok_or_else(|| anyhow!("no artifacts lowered for model {}", cfg.name))?;
        let rt = PjrtRuntime::new(artifacts_dir)?;
        let jit = JitDecompressor::new(model.max_tensor_bytes(), pool);
        Ok(Self {
            rt,
            cfg,
            model,
            jit,
            prefix,
            forwards: 0,
        })
    }

    /// Pre-compile the artifacts for a batch size (embed, layer, head).
    pub fn warmup(&mut self, batch: usize) -> Result<()> {
        for part in ["embed", "layer", "head"] {
            let name = format!("{}_{}_b{}", self.prefix, part, batch);
            self.rt
                .load(&name)
                .with_context(|| format!("artifact {name} (run `make artifacts`?)"))?;
        }
        Ok(())
    }

    fn decode_input(&mut self, tensor: &str, shape: Vec<i64>) -> Result<Input> {
        let (spec, blob) = self
            .model
            .get(tensor)
            .ok_or_else(|| anyhow!("tensor {tensor} missing"))?;
        debug_assert_eq!(
            shape.iter().product::<i64>() as usize,
            spec.n_elem(),
            "{tensor}"
        );
        let blob = blob.clone();
        let bytes = self.jit.with_decoded(&blob, |b| b.to_vec());
        Ok(Input::U8(bytes, shape))
    }

    /// Full forward: `tokens` is `batch × SEQ_LEN` row-major; returns
    /// logits `batch × vocab`.
    pub fn forward(&mut self, tokens: &[i32], batch: usize) -> Result<Vec<f32>> {
        assert_eq!(tokens.len(), batch * SEQ_LEN, "token count");
        let d = self.cfg.hidden as i64;
        let v = self.cfg.vocab as i64;
        let t = SEQ_LEN as i64;
        let b = batch as i64;
        let q_dim = (self.cfg.n_heads * self.cfg.head_dim) as i64;
        let kv_dim = (self.cfg.n_kv_heads * self.cfg.head_dim) as i64;
        let ffn = self.cfg.ffn_inter as i64;

        let embed_art = self.rt.load(&format!("{}_embed_b{batch}", self.prefix))?;
        let layer_art = self.rt.load(&format!("{}_layer_b{batch}", self.prefix))?;
        let head_art = self.rt.load(&format!("{}_head_b{batch}", self.prefix))?;

        // embed
        let embed_w = self.decode_input("embed_tokens", vec![v, d])?;
        let mut x = embed_art.run_f32(&[Input::I32(tokens.to_vec(), vec![b, t]), embed_w])?;

        // layers (norm gains are ones in the synthetic models)
        let ones_d = vec![1.0f32; d as usize];
        for l in 0..self.cfg.n_layers {
            let inputs = vec![
                Input::F32(x, vec![b, t, d]),
                Input::F32(ones_d.clone(), vec![d]),
                self.decode_input(&format!("layers.{l}.attn.q_proj"), vec![q_dim, d])?,
                self.decode_input(&format!("layers.{l}.attn.k_proj"), vec![kv_dim, d])?,
                self.decode_input(&format!("layers.{l}.attn.v_proj"), vec![kv_dim, d])?,
                self.decode_input(&format!("layers.{l}.attn.o_proj"), vec![d, q_dim])?,
                Input::F32(ones_d.clone(), vec![d]),
                self.decode_input(&format!("layers.{l}.mlp.gate"), vec![ffn, d])?,
                self.decode_input(&format!("layers.{l}.mlp.up"), vec![ffn, d])?,
                self.decode_input(&format!("layers.{l}.mlp.down"), vec![d, ffn])?,
            ];
            x = layer_art.run_f32(&inputs)?;
        }

        // head
        let head_w = self.decode_input("lm_head", vec![v, d])?;
        let logits = head_art.run_f32(&[
            Input::F32(x, vec![b, t, d]),
            Input::F32(ones_d, vec![d]),
            head_w,
        ])?;
        self.forwards += 1;
        Ok(logits)
    }

    /// Forward with *pre-decoded raw* weights (bypasses ECF8) — the
    /// baseline for bit-exactness checks (Figure 3's pixel-identity).
    pub fn forward_raw(
        &mut self,
        tokens: &[i32],
        batch: usize,
        raw: &std::collections::HashMap<String, Vec<u8>>,
    ) -> Result<Vec<f32>> {
        assert_eq!(tokens.len(), batch * SEQ_LEN);
        let d = self.cfg.hidden as i64;
        let v = self.cfg.vocab as i64;
        let t = SEQ_LEN as i64;
        let b = batch as i64;
        let q_dim = (self.cfg.n_heads * self.cfg.head_dim) as i64;
        let kv_dim = (self.cfg.n_kv_heads * self.cfg.head_dim) as i64;
        let ffn = self.cfg.ffn_inter as i64;
        let get = |name: &str, shape: Vec<i64>| -> Result<Input> {
            Ok(Input::U8(
                raw.get(name)
                    .ok_or_else(|| anyhow!("raw tensor {name} missing"))?
                    .clone(),
                shape,
            ))
        };

        let embed_art = self.rt.load(&format!("{}_embed_b{batch}", self.prefix))?;
        let layer_art = self.rt.load(&format!("{}_layer_b{batch}", self.prefix))?;
        let head_art = self.rt.load(&format!("{}_head_b{batch}", self.prefix))?;

        let mut x = embed_art.run_f32(&[
            Input::I32(tokens.to_vec(), vec![b, t]),
            get("embed_tokens", vec![v, d])?,
        ])?;
        let ones_d = vec![1.0f32; d as usize];
        for l in 0..self.cfg.n_layers {
            let inputs = vec![
                Input::F32(x, vec![b, t, d]),
                Input::F32(ones_d.clone(), vec![d]),
                get(&format!("layers.{l}.attn.q_proj"), vec![q_dim, d])?,
                get(&format!("layers.{l}.attn.k_proj"), vec![kv_dim, d])?,
                get(&format!("layers.{l}.attn.v_proj"), vec![kv_dim, d])?,
                get(&format!("layers.{l}.attn.o_proj"), vec![d, q_dim])?,
                Input::F32(ones_d.clone(), vec![d]),
                get(&format!("layers.{l}.mlp.gate"), vec![ffn, d])?,
                get(&format!("layers.{l}.mlp.up"), vec![ffn, d])?,
                get(&format!("layers.{l}.mlp.down"), vec![d, ffn])?,
            ];
            x = layer_art.run_f32(&inputs)?;
        }
        let logits = head_art.run_f32(&[
            Input::F32(x, vec![b, t, d]),
            Input::F32(ones_d, vec![d]),
            get("lm_head", vec![v, d])?,
        ])?;
        Ok(logits)
    }

    /// JIT decompression statistics.
    pub fn jit_stats(&self) -> crate::tensormgr::jit::JitStats {
        self.jit.stats()
    }
}

/// Load an artifact and panic-free check it exists (used by benches).
pub fn artifact_available(dir: &std::path::Path, name: &str) -> bool {
    dir.join(format!("{name}.hlo.txt")).exists()
}

#[allow(unused)]
fn _assert_artifact_type_usage(_a: &Artifact) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tiny_llm;
    use crate::util::prng::Xoshiro256;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let d = PjrtRuntime::default_dir();
        if d.join("MANIFEST.txt").exists() {
            Some(d)
        } else {
            eprintln!("skipping: artifacts missing");
            None
        }
    }

    #[test]
    fn tiny_llm_forward_runs_and_is_deterministic() {
        let Some(dir) = artifacts_dir() else { return };
        let cfg = tiny_llm();
        let model = CompressedModel::synthesize(&cfg, 1, None);
        let mut ex = LlmExecutor::new(cfg.clone(), model, dir, None).unwrap();
        ex.warmup(2).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let tokens: Vec<i32> = (0..2 * SEQ_LEN)
            .map(|_| (rng.next_below(cfg.vocab as u64)) as i32)
            .collect();
        let a = ex.forward(&tokens, 2).unwrap();
        let b = ex.forward(&tokens, 2).unwrap();
        assert_eq!(a.len(), 2 * cfg.vocab);
        assert!(a.iter().all(|x| x.is_finite()));
        assert_eq!(a, b, "deterministic");
        assert_eq!(ex.forwards, 2);
    }

    #[test]
    fn compressed_path_is_bit_exact_vs_raw() {
        // Figure 3's losslessness, end-to-end: logits through ECF8
        // decode == logits from the original weights, bit for bit.
        let Some(dir) = artifacts_dir() else { return };
        let cfg = tiny_llm();
        let model = CompressedModel::synthesize(&cfg, 2, None);
        let raw: std::collections::HashMap<String, Vec<u8>> = cfg
            .tensors()
            .iter()
            .map(|s| {
                (
                    s.name.clone(),
                    crate::model::weights::generate_tensor_fp8(s, 2),
                )
            })
            .collect();
        let mut ex = LlmExecutor::new(cfg.clone(), model, dir, None).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(7);
        let tokens: Vec<i32> = (0..2 * SEQ_LEN)
            .map(|_| (rng.next_below(cfg.vocab as u64)) as i32)
            .collect();
        let via_ecf8 = ex.forward(&tokens, 2).unwrap();
        let via_raw = ex.forward_raw(&tokens, 2, &raw).unwrap();
        assert_eq!(via_ecf8.len(), via_raw.len());
        for (i, (a, b)) in via_ecf8.iter().zip(&via_raw).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "logit {i} differs: {a} vs {b}"
            );
        }
    }
}
