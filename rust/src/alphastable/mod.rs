//! The theory of §2: exponent concentration of α-stable weights.
//!
//! * [`two_sided_geometric_pmf`] — Theorem 2.1's law
//!   P(E = k) = (1−q)/(1+q) · q^|k| with q = 2^−α.
//! * [`exponent_entropy_exact`] — the closed-form entropy
//!   H(E) = h₂((1−q)/(1+q)) + 2q/(1+q) · |log₂ q|/(1−q).
//! * [`entropy_lower_bound`] / [`entropy_upper_bound`] — the paper's
//!   bounds α/(1+2^−α) ≤ H(E) ≤ α/(1−2^−α).
//! * [`compression_limit_bits`] — Corollary 2.2's L_min plus sign and
//!   minimal mantissa: the "FP4.67" floor at α = 2.
//! * [`empirical_exponent_pmf`] — measure E = ⌊log₂|X|⌋ from samples for
//!   the theory benches.
//! * [`fit_alpha_from_exponents`] — recover α from an exponent histogram
//!   via the geometric decay rate (used to fit real weight tensors).

use crate::util::stats::entropy_of_probs;

/// q = 2^{-α}.
#[inline]
pub fn q_of_alpha(alpha: f64) -> f64 {
    2f64.powf(-alpha)
}

/// Theorem 2.1: P(E = k) for the two-sided geometric law with parameter
/// q = 2^{-α}.
pub fn two_sided_geometric_pmf(alpha: f64, k: i64) -> f64 {
    assert!(alpha > 0.0, "alpha must be positive");
    let q = q_of_alpha(alpha);
    (1.0 - q) / (1.0 + q) * q.powi(k.unsigned_abs() as i32)
}

/// Binary entropy h₂(p) in bits.
pub fn binary_entropy(p: f64) -> f64 {
    if p <= 0.0 || p >= 1.0 {
        return 0.0;
    }
    -p * p.log2() - (1.0 - p) * (1.0 - p).log2()
}

/// Exact Shannon entropy of the two-sided geometric exponent law:
///
///   H(E) = −log₂ c + |log₂ q| · 2q / ((1+q)(1−q)),  c = (1−q)/(1+q).
///
/// NOTE (reproduction finding, recorded in EXPERIMENTS.md): this is the
/// *correct* closed form, derived by direct summation. The paper's proof
/// of Theorem 2.1 states H(E) = h₂(c) + 2q/(1+q)·|log₂ q|/(1−q), whose
/// first term should be −log₂ c, not h₂(c); see
/// [`exponent_entropy_paper_closed_form`]. The two agree to ~0.2 bits
/// near α = 2 but diverge for small α.
pub fn exponent_entropy_exact(alpha: f64) -> f64 {
    let q = q_of_alpha(alpha);
    let c = (1.0 - q) / (1.0 + q);
    -c.log2() + q.log2().abs() * 2.0 * q / ((1.0 + q) * (1.0 - q))
}

/// The closed form exactly as printed in the paper's proof of Theorem 2.1
/// (kept for comparison; see [`exponent_entropy_exact`]).
pub fn exponent_entropy_paper_closed_form(alpha: f64) -> f64 {
    let q = q_of_alpha(alpha);
    let p0 = (1.0 - q) / (1.0 + q);
    binary_entropy(p0) + (2.0 * q / (1.0 + q)) * (q.log2().abs() / (1.0 - q))
}

/// Lower bound of Theorem 2.1: α / (1 + 2^{-α}).
pub fn entropy_lower_bound(alpha: f64) -> f64 {
    alpha / (1.0 + q_of_alpha(alpha))
}

/// Upper bound of Theorem 2.1: α / (1 − 2^{-α}).
pub fn entropy_upper_bound(alpha: f64) -> f64 {
    alpha / (1.0 - q_of_alpha(alpha))
}

/// Corollary 2.2: minimal bits for a lossless FP format holding α-stable
/// weights — H(E) for the exponent plus one sign bit plus `mantissa_bits`.
/// With α = 2 and a 1-bit mantissa this is the paper's ≈ 4.67-bit floor.
pub fn compression_limit_bits(alpha: f64, mantissa_bits: f64) -> f64 {
    exponent_entropy_exact(alpha) + 1.0 + mantissa_bits
}

/// The paper's headline "FP4.67" number: the §2.3 worst case built from
/// the *upper bound* at α = 2 (2.67 bits) + 1 sign + 1 mantissa bit.
pub fn paper_fp467_floor() -> f64 {
    entropy_upper_bound(2.0) + 2.0
}

/// Empirical PMF of E = ⌊log₂|X|⌋ over `samples`, returned as
/// (offset, probs) where probs[i] is P(E = offset + i). Zeros and
/// non-finite values are skipped.
pub fn empirical_exponent_pmf(samples: &[f64]) -> (i64, Vec<f64>) {
    let mut counts: std::collections::BTreeMap<i64, u64> = std::collections::BTreeMap::new();
    let mut total = 0u64;
    for &x in samples {
        let a = x.abs();
        if !a.is_finite() || a == 0.0 {
            continue;
        }
        let e = a.log2().floor() as i64;
        *counts.entry(e).or_insert(0) += 1;
        total += 1;
    }
    if total == 0 {
        return (0, Vec::new());
    }
    let lo = *counts.keys().next().unwrap();
    let hi = *counts.keys().last().unwrap();
    let mut probs = vec![0f64; (hi - lo + 1) as usize];
    for (k, c) in counts {
        probs[(k - lo) as usize] = c as f64 / total as f64;
    }
    (lo, probs)
}

/// Shannon entropy (bits) of an empirical exponent PMF.
pub fn empirical_exponent_entropy(samples: &[f64]) -> f64 {
    let (_, probs) = empirical_exponent_pmf(samples);
    entropy_of_probs(&probs)
}

/// Fit α from an exponent histogram by the tail decay of the geometric
/// law: on the decaying flank, P(E = k+1)/P(E = k) = q = 2^{-α}, so a
/// least-squares line through log₂ P against distance-from-mode has
/// slope −α. `counts[i]` is the count of exponent value `offset + i`.
pub fn fit_alpha_from_exponents(offset: i64, counts: &[u64]) -> Option<f64> {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return None;
    }
    let _ = offset; // the fit is shift-invariant
    let mode_idx = counts
        .iter()
        .enumerate()
        .max_by_key(|&(_, &c)| c)
        .map(|(i, _)| i)?;
    // collect (distance-from-mode, log₂ p) on the right flank, which the
    // FP8 alphabet truncates least
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for (i, &c) in counts.iter().enumerate().skip(mode_idx) {
        if c == 0 {
            break;
        }
        let d = (i - mode_idx) as f64;
        let p = c as f64 / total as f64;
        xs.push(d);
        ys.push(p.log2());
    }
    if xs.len() < 2 {
        return None;
    }
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return None;
    }
    let slope = (n * sxy - sx * sy) / denom;
    Some((-slope).clamp(0.05, 2.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;
    use crate::util::sampling::alpha_stable_std;

    #[test]
    fn pmf_sums_to_one() {
        for alpha in [0.5, 1.0, 1.5, 2.0] {
            let sum: f64 = (-200..=200)
                .map(|k| two_sided_geometric_pmf(alpha, k))
                .sum();
            assert!((sum - 1.0).abs() < 1e-9, "alpha={alpha} sum={sum}");
        }
    }

    #[test]
    fn pmf_symmetric_and_decaying() {
        let alpha = 1.3;
        for k in 1..10i64 {
            assert_eq!(
                two_sided_geometric_pmf(alpha, k),
                two_sided_geometric_pmf(alpha, -k)
            );
            assert!(two_sided_geometric_pmf(alpha, k) < two_sided_geometric_pmf(alpha, k - 1));
        }
    }

    #[test]
    fn entropy_matches_direct_sum() {
        for alpha in [0.7, 1.0, 1.5, 2.0] {
            let direct: f64 = (-500..=500)
                .map(|k| {
                    let p = two_sided_geometric_pmf(alpha, k);
                    if p > 0.0 {
                        -p * p.log2()
                    } else {
                        0.0
                    }
                })
                .sum();
            let exact = exponent_entropy_exact(alpha);
            assert!(
                (direct - exact).abs() < 1e-6,
                "alpha={alpha} direct={direct} exact={exact}"
            );
        }
    }

    #[test]
    fn theorem_bounds_hold_in_gaussian_regime() {
        // The paper's bounds α/(1+q) ≤ H(E) ≤ α/(1−q) hold in the regime
        // its models live in (α ≳ 1.4, "LLMs ≈ 2"), which is where the
        // paper applies them.
        for i in 0..=10 {
            let alpha = 1.5 + i as f64 * 0.05;
            let h = exponent_entropy_exact(alpha);
            assert!(
                entropy_lower_bound(alpha) <= h + 1e-9,
                "alpha={alpha} lb={} h={h}",
                entropy_lower_bound(alpha)
            );
            assert!(
                h <= entropy_upper_bound(alpha) + 1e-9,
                "alpha={alpha} ub={} h={h}",
                entropy_upper_bound(alpha)
            );
        }
    }

    #[test]
    fn theorem_upper_bound_fails_for_small_alpha() {
        // Reproduction finding (EXPERIMENTS.md §Deviations): Theorem 2.1's
        // upper bound is violated for α ≲ 1.4 — the true entropy of the
        // two-sided geometric law exceeds α/(1−2^−α) there. "H(E) is
        // finite for all α > 0" still holds.
        for alpha in [0.2, 0.5, 0.8, 1.0, 1.2] {
            let h = exponent_entropy_exact(alpha);
            assert!(
                h > entropy_upper_bound(alpha),
                "expected violation at alpha={alpha}: h={h} ub={}",
                entropy_upper_bound(alpha)
            );
            assert!(h.is_finite());
        }
    }

    #[test]
    fn paper_closed_form_deviates_from_direct_sum() {
        // The printed closed form (h₂ first term) understates/overstates
        // the direct sum away from α = 2; near α = 2 they are close.
        let d2 = (exponent_entropy_paper_closed_form(2.0) - exponent_entropy_exact(2.0)).abs();
        assert!(d2 < 0.25, "near-Gaussian deviation {d2}");
        let d07 = (exponent_entropy_paper_closed_form(0.7) - exponent_entropy_exact(0.7)).abs();
        assert!(d07 > 1.0, "small-alpha deviation {d07}");
    }

    #[test]
    fn paper_numerical_instance_alpha2() {
        // §2.3: 1.6 <= H(E) <= 2.67 at α = 2, floor ≈ 4.67 bits
        assert!((entropy_lower_bound(2.0) - 1.6).abs() < 1e-9);
        assert!((entropy_upper_bound(2.0) - 8.0 / 3.0).abs() < 1e-9);
        let h = exponent_entropy_exact(2.0);
        assert!(h > 1.6 && h < 2.67, "H(E)={h}");
        let floor = compression_limit_bits(2.0, 1.0);
        assert!(floor > 3.6 && floor < 4.67 + 1e-9, "floor={floor}");
    }

    #[test]
    fn sampled_exponents_follow_geometric_law() {
        // Empirical P(E=k)/P(E=k+1) on the tail ≈ 2^α for α-stable samples.
        let mut rng = Xoshiro256::seed_from_u64(21);
        let alpha = 1.5;
        let samples: Vec<f64> = (0..2_000_000)
            .map(|_| alpha_stable_std(&mut rng, alpha))
            .collect();
        let (lo, probs) = empirical_exponent_pmf(&samples);
        // k = 5 (|X| ∈ [32,64)) is far enough into the power-law tail for
        // α = 1.5 while keeping counts large enough for a stable ratio
        let idx = (5 - lo) as usize;
        let ratio = probs[idx] / probs[idx + 1];
        let expect = 2f64.powf(alpha);
        assert!(
            (ratio / expect - 1.0).abs() < 0.15,
            "ratio={ratio} expect={expect}"
        );
    }

    #[test]
    fn empirical_entropy_finite_and_low() {
        let mut rng = Xoshiro256::seed_from_u64(22);
        for alpha in [1.2, 1.6, 2.0] {
            let samples: Vec<f64> = (0..500_000)
                .map(|_| alpha_stable_std(&mut rng, alpha))
                .collect();
            let h = empirical_exponent_entropy(&samples);
            assert!(h > 1.0 && h < 6.0, "alpha={alpha} h={h}");
        }
    }

    #[test]
    fn fit_alpha_recovers_generator() {
        let mut rng = Xoshiro256::seed_from_u64(23);
        let alpha = 1.5;
        let mut counts_map: std::collections::BTreeMap<i64, u64> = Default::default();
        for _ in 0..1_000_000 {
            let x = alpha_stable_std(&mut rng, alpha).abs();
            if x > 0.0 && x.is_finite() {
                *counts_map.entry(x.log2().floor() as i64).or_insert(0) += 1;
            }
        }
        let lo = *counts_map.keys().next().unwrap();
        let hi = *counts_map.keys().last().unwrap();
        let mut counts = vec![0u64; (hi - lo + 1) as usize];
        for (k, c) in counts_map {
            counts[(k - lo) as usize] = c;
        }
        let fitted = fit_alpha_from_exponents(lo, &counts).unwrap();
        assert!((fitted - alpha).abs() < 0.3, "fitted={fitted}");
    }

    #[test]
    fn empty_samples_handled() {
        assert_eq!(empirical_exponent_pmf(&[]).1.len(), 0);
        assert_eq!(empirical_exponent_entropy(&[0.0, 0.0]), 0.0);
        assert!(fit_alpha_from_exponents(0, &[]).is_none());
        assert!(fit_alpha_from_exponents(0, &[0, 0]).is_none());
    }
}
