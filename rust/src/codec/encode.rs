//! ECF8 encoder (§3.1): Huffman-code the exponent fields, pack the
//! sign/mantissa nibbles, and emit the synchronization metadata (per-thread
//! gaps, per-block output positions) that lets thread blocks decode
//! autonomously.
//!
//! Two equivalent implementations:
//!
//! * [`encode_with_code`] — the straightforward sequential pass;
//! * [`encode_with_code_parallel`] — a block-sharded two-pass encoder
//!   whose output is **byte-identical** to the sequential one. Pass 1
//!   computes per-chunk code-length sums (a histogram × length dot
//!   product) on the thread pool and prefix-sums them into exact bit
//!   offsets; pass 2 writes every chunk's bitstream, nibble plane, and
//!   window (gap / first-element) records independently, with only the
//!   two bit-shared boundary bytes per chunk OR-merged sequentially at
//!   the end.

use super::{Ecf8Blob, Ecf8Params, Fp8Format};
use crate::huffman::bitstream::BitWriter;
use crate::huffman::canonical::CanonicalCode;
use crate::util::stats::shannon_entropy;
use crate::util::threadpool::ThreadPool;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Histogram of exponent symbols of an FP8 byte tensor.
pub fn exponent_histogram(data: &[u8], format: Fp8Format) -> Vec<u64> {
    let mut hist = vec![0u64; format.alphabet_size()];
    match format {
        Fp8Format::E4M3 => {
            // byte-level histogram then fold: touches each byte once and
            // keeps counters in L1 (perf pass)
            let bhist = crate::util::stats::byte_histogram(data);
            for (b, &c) in bhist.iter().enumerate() {
                hist[(b >> 3) & 0x0F] += c;
            }
        }
        Fp8Format::E5M2 => {
            let bhist = crate::util::stats::byte_histogram(data);
            for (b, &c) in bhist.iter().enumerate() {
                hist[(b >> 2) & 0x1F] += c;
            }
        }
    }
    hist
}

/// Shannon entropy (bits/element) of the exponent field of `data` — the
/// quantity Figure 1 plots per transformer block.
pub fn exponent_entropy(data: &[u8], format: Fp8Format) -> f64 {
    shannon_entropy(&exponent_histogram(data, format))
}

/// Encode an FP8 byte tensor into an [`Ecf8Blob`].
pub fn encode(data: &[u8], format: Fp8Format, params: Ecf8Params) -> Ecf8Blob {
    let hist = exponent_histogram(data, format);
    let code = CanonicalCode::from_frequencies(&hist);
    encode_with_code(data, format, params, &code)
}

/// Encode with an externally supplied code book (used by the ablation
/// benches to measure suboptimal codes, and by the model store to share
/// one code book across tensors of a layer).
pub fn encode_with_code(
    data: &[u8],
    format: Fp8Format,
    params: Ecf8Params,
    code: &CanonicalCode,
) -> Ecf8Blob {
    let n_elem = data.len();
    let bt = params.bytes_per_thread;
    let window_bits = (bt * 8) as u64;

    // --- streams ---------------------------------------------------------
    let mut writer = BitWriter::with_capacity(n_elem / 2 + 16);
    let mut packed = vec![0u8; n_elem.div_ceil(2)];
    // first element of each pair goes in the high nibble
    // gap of thread t = bit offset, within t's window, of the first
    // codeword starting there; first_sym records the matching element
    // index so block output positions fall out of it.
    let mut gaps4: Vec<u8> = Vec::new(); // one nibble value per thread (unpacked)
    let mut first_sym: Vec<u64> = Vec::new();

    for (i, &byte) in data.iter().enumerate() {
        let (sym, rest) = format.split(byte);
        packed[i / 2] |= rest << (4 - (i % 2) * 4);

        let p = writer.bit_len();
        let thread = (p / window_bits) as usize;
        // a codeword starts in this window; if it's the first, record it
        while gaps4.len() <= thread {
            let t = gaps4.len() as u64;
            // Codeword starts are at most MAX_CODE_LEN(=16) bits apart and
            // windows are >= 64 bits, so the only window that can be
            // "entered" here is `thread` itself.
            debug_assert!(
                t == thread as u64,
                "window {t} skipped (no codeword start); window_bits={window_bits}"
            );
            let gap = p - t * window_bits;
            debug_assert!(gap < 16, "gap {gap} does not fit in 4 bits");
            gaps4.push(gap as u8);
            first_sym.push(i as u64);
        }
        let (c, l) = code.encode(sym as usize);
        writer.write(c, l);
    }

    let encoded_bits = writer.bit_len();
    let mut encoded = writer.finish();

    // --- block geometry + padding ----------------------------------------
    let n_threads_used = gaps4.len();
    let tpb = params.threads_per_block;
    let n_blocks = n_threads_used.div_ceil(tpb).max(1);
    let n_threads = n_blocks * tpb;
    // trailing windows own no codeword start
    gaps4.resize(n_threads, 0);
    first_sym.resize(n_threads, n_elem as u64);
    // pad the stream so every thread can load B+2 bytes (we give the
    // decoder a full 8-byte slack for its u64 window loads)
    encoded.resize(n_blocks * params.block_bytes() + 8, 0);

    // pack gaps two per byte, even thread in the high nibble (Alg. 1 l.5)
    let mut gaps = vec![0u8; n_threads.div_ceil(2)];
    for (t, &g) in gaps4.iter().enumerate() {
        gaps[t / 2] |= g << (4 - (t % 2) * 4);
    }

    // outpos[b] = index of the first element whose codeword starts in
    // block b; outpos[n_blocks] = n_elem (Alg. 1 uses it as the write
    // bound of the last block).
    let mut outpos = Vec::with_capacity(n_blocks + 1);
    for b in 0..n_blocks {
        outpos.push(first_sym[b * tpb]);
    }
    outpos.push(n_elem as u64);

    Ecf8Blob {
        format,
        params,
        n_elem,
        code_lengths: code.lengths.iter().map(|&l| l as u8).collect(),
        encoded: encoded.into(),
        encoded_bits,
        packed: packed.into(),
        gaps: gaps.into(),
        outpos,
    }
}

/// Elements per parallel-encode chunk. Even, so each chunk owns a
/// disjoint byte range of the packed nibble plane (two nibbles per byte).
const PAR_CHUNK: usize = 1 << 16;

/// Parallel [`encode`]: same histogram + code construction, chunked
/// two-pass bitstream emission on `pool`.
pub fn encode_parallel(
    data: &[u8],
    format: Fp8Format,
    params: Ecf8Params,
    pool: &ThreadPool,
) -> Ecf8Blob {
    let hist = exponent_histogram(data, format);
    let code = CanonicalCode::from_frequencies(&hist);
    encode_with_code_parallel(data, format, params, &code, pool)
}

/// Per-chunk output of parallel pass 2, merged sequentially afterwards.
struct ChunkOut {
    /// index + value of the chunk's first (bit-shared) stream byte
    first_byte: usize,
    first_val: u8,
    /// index + value of the chunk's last (bit-shared) stream byte
    last_byte: usize,
    last_val: u8,
    /// (window index, gap bits, first element index) candidates for every
    /// window whose first codeword start lies in this chunk — the first
    /// candidate may duplicate the previous chunk's last window and is
    /// dropped at merge time
    windows: Vec<(usize, u8, u64)>,
}

/// Two-pass block-sharded encoder, byte-identical to
/// [`encode_with_code`]. See the module docs for the pass structure.
pub fn encode_with_code_parallel(
    data: &[u8],
    format: Fp8Format,
    params: Ecf8Params,
    code: &CanonicalCode,
    pool: &ThreadPool,
) -> Ecf8Blob {
    let n_elem = data.len();
    // small tensors: chunking overhead dominates, and the sequential
    // encoder also handles the empty-tensor edge cases
    if n_elem < 2 * PAR_CHUNK {
        return encode_with_code(data, format, params, code);
    }
    let n_chunks = n_elem.div_ceil(PAR_CHUNK);
    let window_bits = (params.bytes_per_thread * 8) as u64;

    // ---- Pass 1: exact bit offset of every chunk ------------------------
    let chunk_bits: Vec<AtomicU64> = (0..n_chunks).map(|_| AtomicU64::new(0)).collect();
    {
        let chunk_bits = &chunk_bits;
        pool.scope_chunks(n_chunks, pool.size() * 4, move |_, cs, ce| {
            for c in cs..ce {
                let lo = c * PAR_CHUNK;
                let hi = ((c + 1) * PAR_CHUNK).min(n_elem);
                let mut h = [0u64; 32];
                for &b in &data[lo..hi] {
                    h[format.split(b).0 as usize] += 1;
                }
                let bits: u64 = h
                    .iter()
                    .zip(code.lengths.iter())
                    .map(|(&cnt, &len)| cnt * len as u64)
                    .sum();
                chunk_bits[c].store(bits, Ordering::Relaxed);
            }
        });
    }
    let mut start_bit = vec![0u64; n_chunks + 1];
    for c in 0..n_chunks {
        start_bit[c + 1] = start_bit[c] + chunk_bits[c].load(Ordering::Relaxed);
    }
    let total_bits = start_bit[n_chunks];

    // ---- Geometry (identical to the sequential derivation) --------------
    let last_len = code.encode(format.split(data[n_elem - 1]).0 as usize).1 as u64;
    let last_start = total_bits - last_len;
    let n_threads_used = (last_start / window_bits) as usize + 1;
    let tpb = params.threads_per_block;
    let n_blocks = n_threads_used.div_ceil(tpb).max(1);
    let n_threads = n_blocks * tpb;

    let mut encoded = vec![0u8; n_blocks * params.block_bytes() + 8];
    let mut packed = vec![0u8; n_elem.div_ceil(2)];

    // ---- Pass 2: independent chunk emission ------------------------------
    let results: Vec<Mutex<Option<ChunkOut>>> =
        (0..n_chunks).map(|_| Mutex::new(None)).collect();
    {
        let results = &results;
        let start_bit = &start_bit;
        let enc_addr = encoded.as_mut_ptr() as usize;
        let packed_addr = packed.as_mut_ptr() as usize;
        pool.scope_chunks(n_chunks, pool.size() * 4, move |_, cs, ce| {
            for c in cs..ce {
                let lo = c * PAR_CHUNK;
                let hi = ((c + 1) * PAR_CHUNK).min(n_elem);
                let s_bit = start_bit[c];
                let lead = (s_bit % 8) as u32;
                let mut w = BitWriter::with_capacity((hi - lo) / 2 + 16);
                if lead > 0 {
                    w.write(0, lead);
                }
                // SAFETY: lo is even, so chunks own disjoint byte ranges
                // [lo/2, ceil(hi/2)) of the packed plane.
                let pk = unsafe {
                    std::slice::from_raw_parts_mut(
                        (packed_addr as *mut u8).add(lo / 2),
                        hi.div_ceil(2) - lo / 2,
                    )
                };
                let mut windows: Vec<(usize, u8, u64)> = Vec::new();
                let mut p = s_bit;
                let mut prev_window = u64::MAX;
                for (i, &byte) in data[lo..hi].iter().enumerate() {
                    let idx = lo + i;
                    let (sym, rest) = format.split(byte);
                    pk[i / 2] |= rest << (4 - (i % 2) * 4);
                    let wd = p / window_bits;
                    if wd != prev_window {
                        // First codeword start this chunk sees in window
                        // `wd`. Candidate only: when the window's true
                        // first start lies in an earlier chunk this gap
                        // is an overshoot (possibly ≥ 16) and the merge
                        // discards it — the 4-bit bound is asserted there,
                        // on accepted records.
                        let gap = p - wd * window_bits;
                        windows.push((wd as usize, gap as u8, idx as u64));
                        prev_window = wd;
                    }
                    let (cw, l) = code.encode(sym as usize);
                    w.write(cw, l);
                    p += l as u64;
                }
                debug_assert_eq!(p, start_bit[c + 1]);
                let bytes = w.finish();
                let first_byte = (s_bit / 8) as usize;
                debug_assert_eq!(
                    first_byte + bytes.len() - 1,
                    ((start_bit[c + 1] - 1) / 8) as usize
                );
                if bytes.len() > 2 {
                    // SAFETY: interior bytes (first_byte, last_byte) are
                    // bit-exclusive to this chunk; only the two boundary
                    // bytes can share bits with neighbours and those are
                    // OR-merged sequentially below.
                    let dst = unsafe {
                        std::slice::from_raw_parts_mut(
                            (enc_addr as *mut u8).add(first_byte + 1),
                            bytes.len() - 2,
                        )
                    };
                    dst.copy_from_slice(&bytes[1..bytes.len() - 1]);
                }
                *results[c].lock().unwrap() = Some(ChunkOut {
                    first_byte,
                    first_val: bytes[0],
                    last_byte: first_byte + bytes.len() - 1,
                    last_val: *bytes.last().unwrap(),
                    windows,
                });
            }
        });
    }

    // ---- Sequential merge: boundary bytes + window metadata --------------
    let mut gaps4: Vec<u8> = Vec::with_capacity(n_threads);
    let mut first_sym: Vec<u64> = Vec::with_capacity(n_threads);
    for slot in &results {
        let out = slot.lock().unwrap().take().expect("chunk emitted");
        encoded[out.first_byte] |= out.first_val;
        encoded[out.last_byte] |= out.last_val;
        for (wd, gap, first) in out.windows {
            if wd == gaps4.len() {
                // genuinely the first codeword start in window `wd`:
                // consecutive starts are ≤ MAX_CODE_LEN = 16 bits apart,
                // so the accepted gap always fits the nibble
                debug_assert!(gap < 16, "gap {gap} does not fit in 4 bits");
                gaps4.push(gap);
                first_sym.push(first);
            } else {
                // boundary window already claimed by the previous chunk
                debug_assert!(wd < gaps4.len(), "window {wd} skipped");
            }
        }
    }
    debug_assert_eq!(gaps4.len(), n_threads_used, "window census mismatch");

    // ---- Tail identical to the sequential encoder ------------------------
    gaps4.resize(n_threads, 0);
    first_sym.resize(n_threads, n_elem as u64);
    let mut gaps = vec![0u8; n_threads.div_ceil(2)];
    for (t, &g) in gaps4.iter().enumerate() {
        gaps[t / 2] |= g << (4 - (t % 2) * 4);
    }
    let mut outpos = Vec::with_capacity(n_blocks + 1);
    for b in 0..n_blocks {
        outpos.push(first_sym[b * tpb]);
    }
    outpos.push(n_elem as u64);

    Ecf8Blob {
        format,
        params,
        n_elem,
        code_lengths: code.lengths.iter().map(|&l| l as u8).collect(),
        encoded: encoded.into(),
        encoded_bits: total_bits,
        packed: packed.into(),
        gaps: gaps.into(),
        outpos,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn weight_like_bytes(n: usize, seed: u64) -> Vec<u8> {
        // E4M3 bytes with concentrated exponents (like trained weights)
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                let x = (crate::util::sampling::normal(&mut rng) * 0.05) as f32;
                crate::fp8::F8E4M3::from_f32(x).to_bits()
            })
            .collect()
    }

    #[test]
    fn histogram_counts_every_element() {
        let data = weight_like_bytes(10_000, 1);
        let hist = exponent_histogram(&data, Fp8Format::E4M3);
        assert_eq!(hist.iter().sum::<u64>(), 10_000);
        assert_eq!(hist.len(), 16);
    }

    #[test]
    fn entropy_of_concentrated_weights_is_low() {
        let data = weight_like_bytes(100_000, 2);
        let h = exponent_entropy(&data, Fp8Format::E4M3);
        // the paper's Figure 1 band
        assert!(h > 1.0 && h < 4.0, "H(E)={h}");
    }

    #[test]
    fn encode_produces_consistent_metadata() {
        let data = weight_like_bytes(50_000, 3);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        assert_eq!(blob.n_elem, 50_000);
        assert_eq!(blob.packed.len(), 25_000);
        // stream padded to block multiple + slack
        assert_eq!(
            blob.encoded.len(),
            blob.n_blocks() * blob.params.block_bytes() + 8
        );
        // outpos monotone, ending at n_elem
        assert!(blob.outpos.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*blob.outpos.last().unwrap(), 50_000);
        assert_eq!(blob.outpos[0], 0);
        // gaps all < 16 by construction (they're nibbles)
        assert_eq!(blob.gaps.len(), blob.n_threads().div_ceil(2));
    }

    #[test]
    fn compressed_smaller_than_raw_for_weights() {
        let data = weight_like_bytes(200_000, 4);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        let saving = blob.memory_saving();
        // exponent entropy ~2-3 bits => ~ (8 - (4 + H)) / 8 = 10..25 %
        assert!(saving > 0.05, "saving={saving}");
        assert!(saving < 0.5, "saving={saving}");
    }

    #[test]
    fn encode_empty_tensor() {
        let blob = encode(&[], Fp8Format::E4M3, Ecf8Params::default());
        assert_eq!(blob.n_elem, 0);
        assert_eq!(blob.n_blocks(), 1);
        assert_eq!(blob.outpos, vec![0, 0]);
    }

    #[test]
    fn encoded_bits_matches_code_lengths() {
        let data = weight_like_bytes(10_000, 5);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        let code = blob.code();
        let expect: u64 = data
            .iter()
            .map(|&b| code.encode(Fp8Format::E4M3.split(b).0 as usize).1 as u64)
            .sum();
        assert_eq!(blob.encoded_bits, expect);
    }

    fn assert_blob_eq(a: &crate::codec::Ecf8Blob, b: &crate::codec::Ecf8Blob) {
        assert_eq!(a.n_elem, b.n_elem);
        assert_eq!(a.encoded_bits, b.encoded_bits);
        assert_eq!(a.encoded, b.encoded, "encoded stream differs");
        assert_eq!(a.packed, b.packed, "packed nibbles differ");
        assert_eq!(a.gaps, b.gaps, "gap metadata differs");
        assert_eq!(a.outpos, b.outpos, "outpos differs");
        assert_eq!(a.code_lengths, b.code_lengths);
    }

    #[test]
    fn parallel_encode_byte_identical_to_sequential() {
        let pool = ThreadPool::new(4);
        // sizes straddling the chunk boundary and odd lengths that leave
        // a half-filled packed byte at a chunk edge
        for n in [
            2 * super::PAR_CHUNK,
            2 * super::PAR_CHUNK + 1,
            3 * super::PAR_CHUNK - 1,
            777_777,
        ] {
            let data = weight_like_bytes(n, n as u64);
            let seq = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
            let par = encode_parallel(&data, Fp8Format::E4M3, Ecf8Params::default(), &pool);
            assert_blob_eq(&seq, &par);
        }
    }

    #[test]
    fn parallel_encode_small_input_falls_back() {
        let pool = ThreadPool::new(2);
        for n in [0usize, 1, 100, super::PAR_CHUNK] {
            let data = weight_like_bytes(n, 9);
            let seq = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
            let par = encode_parallel(&data, Fp8Format::E4M3, Ecf8Params::default(), &pool);
            assert_blob_eq(&seq, &par);
        }
    }

    #[test]
    fn property_parallel_encode_identity() {
        use crate::util::quickprop::{property, Gen};
        property("parallel encode == sequential encode", 12, |g: &mut Gen| {
            // pool per case: keeps the closure free of captured channel
            // state (quickprop requires RefUnwindSafe closures)
            let pool = ThreadPool::new(3);
            // straddle 2–3 chunk boundaries with adversarial content
            let n = g.usize_in(2 * super::PAR_CHUNK..=3 * super::PAR_CHUNK);
            let data: Vec<u8> = if g.bool() {
                (0..n).map(|_| g.u8()).collect()
            } else {
                weight_like_bytes(n, g.u64())
            };
            let params = *g.choose(&[
                Ecf8Params::default(),
                Ecf8Params {
                    bytes_per_thread: 4,
                    threads_per_block: 128,
                },
            ]);
            let fmt = *g.choose(&[Fp8Format::E4M3, Fp8Format::E5M2]);
            let hist = exponent_histogram(&data, fmt);
            let code = CanonicalCode::from_frequencies(&hist);
            let seq = encode_with_code(&data, fmt, params, &code);
            let par = encode_with_code_parallel(&data, fmt, params, &code, &pool);
            assert_blob_eq(&seq, &par);
            // and the parallel blob decodes losslessly
            assert_eq!(crate::codec::decompress_fp8(&par), data);
        });
    }

    #[test]
    fn uniform_random_bytes_do_not_compress() {
        // adversarial input: uniform exponents => H(E) ~ 4 bits; ECF8
        // should report ~zero / negative saving but remain lossless
        // (losslessness is asserted in decode tests).
        let mut rng = Xoshiro256::seed_from_u64(6);
        let data: Vec<u8> = (0..100_000).map(|_| (rng.next_u64() >> 56) as u8).collect();
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        assert!(blob.memory_saving() < 0.03, "saving={}", blob.memory_saving());
    }
}
