//! ECF8 encoder (§3.1): Huffman-code the exponent fields, pack the
//! sign/mantissa nibbles, and emit the synchronization metadata (per-thread
//! gaps, per-block output positions) that lets thread blocks decode
//! autonomously.

use super::{Ecf8Blob, Ecf8Params, Fp8Format};
use crate::huffman::bitstream::BitWriter;
use crate::huffman::canonical::CanonicalCode;
use crate::util::stats::shannon_entropy;

/// Histogram of exponent symbols of an FP8 byte tensor.
pub fn exponent_histogram(data: &[u8], format: Fp8Format) -> Vec<u64> {
    let mut hist = vec![0u64; format.alphabet_size()];
    match format {
        Fp8Format::E4M3 => {
            // byte-level histogram then fold: touches each byte once and
            // keeps counters in L1 (perf pass)
            let bhist = crate::util::stats::byte_histogram(data);
            for (b, &c) in bhist.iter().enumerate() {
                hist[(b >> 3) & 0x0F] += c;
            }
        }
        Fp8Format::E5M2 => {
            let bhist = crate::util::stats::byte_histogram(data);
            for (b, &c) in bhist.iter().enumerate() {
                hist[(b >> 2) & 0x1F] += c;
            }
        }
    }
    hist
}

/// Shannon entropy (bits/element) of the exponent field of `data` — the
/// quantity Figure 1 plots per transformer block.
pub fn exponent_entropy(data: &[u8], format: Fp8Format) -> f64 {
    shannon_entropy(&exponent_histogram(data, format))
}

/// Encode an FP8 byte tensor into an [`Ecf8Blob`].
pub fn encode(data: &[u8], format: Fp8Format, params: Ecf8Params) -> Ecf8Blob {
    let hist = exponent_histogram(data, format);
    let code = CanonicalCode::from_frequencies(&hist);
    encode_with_code(data, format, params, &code)
}

/// Encode with an externally supplied code book (used by the ablation
/// benches to measure suboptimal codes, and by the model store to share
/// one code book across tensors of a layer).
pub fn encode_with_code(
    data: &[u8],
    format: Fp8Format,
    params: Ecf8Params,
    code: &CanonicalCode,
) -> Ecf8Blob {
    let n_elem = data.len();
    let bt = params.bytes_per_thread;
    let window_bits = (bt * 8) as u64;

    // --- streams ---------------------------------------------------------
    let mut writer = BitWriter::with_capacity(n_elem / 2 + 16);
    let mut packed = vec![0u8; n_elem.div_ceil(2)];
    // first element of each pair goes in the high nibble
    // gap of thread t = bit offset, within t's window, of the first
    // codeword starting there; first_sym records the matching element
    // index so block output positions fall out of it.
    let mut gaps4: Vec<u8> = Vec::new(); // one nibble value per thread (unpacked)
    let mut first_sym: Vec<u64> = Vec::new();

    for (i, &byte) in data.iter().enumerate() {
        let (sym, rest) = format.split(byte);
        packed[i / 2] |= rest << (4 - (i % 2) * 4);

        let p = writer.bit_len();
        let thread = (p / window_bits) as usize;
        // a codeword starts in this window; if it's the first, record it
        while gaps4.len() <= thread {
            let t = gaps4.len() as u64;
            // Codeword starts are at most MAX_CODE_LEN(=16) bits apart and
            // windows are >= 64 bits, so the only window that can be
            // "entered" here is `thread` itself.
            debug_assert!(
                t == thread as u64,
                "window {t} skipped (no codeword start); window_bits={window_bits}"
            );
            let gap = p - t * window_bits;
            debug_assert!(gap < 16, "gap {gap} does not fit in 4 bits");
            gaps4.push(gap as u8);
            first_sym.push(i as u64);
        }
        let (c, l) = code.encode(sym as usize);
        writer.write(c, l);
    }

    let encoded_bits = writer.bit_len();
    let mut encoded = writer.finish();

    // --- block geometry + padding ----------------------------------------
    let n_threads_used = gaps4.len();
    let tpb = params.threads_per_block;
    let n_blocks = n_threads_used.div_ceil(tpb).max(1);
    let n_threads = n_blocks * tpb;
    // trailing windows own no codeword start
    gaps4.resize(n_threads, 0);
    first_sym.resize(n_threads, n_elem as u64);
    // pad the stream so every thread can load B+2 bytes (we give the
    // decoder a full 8-byte slack for its u64 window loads)
    encoded.resize(n_blocks * params.block_bytes() + 8, 0);

    // pack gaps two per byte, even thread in the high nibble (Alg. 1 l.5)
    let mut gaps = vec![0u8; n_threads.div_ceil(2)];
    for (t, &g) in gaps4.iter().enumerate() {
        gaps[t / 2] |= g << (4 - (t % 2) * 4);
    }

    // outpos[b] = index of the first element whose codeword starts in
    // block b; outpos[n_blocks] = n_elem (Alg. 1 uses it as the write
    // bound of the last block).
    let mut outpos = Vec::with_capacity(n_blocks + 1);
    for b in 0..n_blocks {
        outpos.push(first_sym[b * tpb]);
    }
    outpos.push(n_elem as u64);

    Ecf8Blob {
        format,
        params,
        n_elem,
        code_lengths: code.lengths.iter().map(|&l| l as u8).collect(),
        encoded,
        encoded_bits,
        packed,
        gaps,
        outpos,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn weight_like_bytes(n: usize, seed: u64) -> Vec<u8> {
        // E4M3 bytes with concentrated exponents (like trained weights)
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                let x = (crate::util::sampling::normal(&mut rng) * 0.05) as f32;
                crate::fp8::F8E4M3::from_f32(x).to_bits()
            })
            .collect()
    }

    #[test]
    fn histogram_counts_every_element() {
        let data = weight_like_bytes(10_000, 1);
        let hist = exponent_histogram(&data, Fp8Format::E4M3);
        assert_eq!(hist.iter().sum::<u64>(), 10_000);
        assert_eq!(hist.len(), 16);
    }

    #[test]
    fn entropy_of_concentrated_weights_is_low() {
        let data = weight_like_bytes(100_000, 2);
        let h = exponent_entropy(&data, Fp8Format::E4M3);
        // the paper's Figure 1 band
        assert!(h > 1.0 && h < 4.0, "H(E)={h}");
    }

    #[test]
    fn encode_produces_consistent_metadata() {
        let data = weight_like_bytes(50_000, 3);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        assert_eq!(blob.n_elem, 50_000);
        assert_eq!(blob.packed.len(), 25_000);
        // stream padded to block multiple + slack
        assert_eq!(
            blob.encoded.len(),
            blob.n_blocks() * blob.params.block_bytes() + 8
        );
        // outpos monotone, ending at n_elem
        assert!(blob.outpos.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*blob.outpos.last().unwrap(), 50_000);
        assert_eq!(blob.outpos[0], 0);
        // gaps all < 16 by construction (they're nibbles)
        assert_eq!(blob.gaps.len(), blob.n_threads().div_ceil(2));
    }

    #[test]
    fn compressed_smaller_than_raw_for_weights() {
        let data = weight_like_bytes(200_000, 4);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        let saving = blob.memory_saving();
        // exponent entropy ~2-3 bits => ~ (8 - (4 + H)) / 8 = 10..25 %
        assert!(saving > 0.05, "saving={saving}");
        assert!(saving < 0.5, "saving={saving}");
    }

    #[test]
    fn encode_empty_tensor() {
        let blob = encode(&[], Fp8Format::E4M3, Ecf8Params::default());
        assert_eq!(blob.n_elem, 0);
        assert_eq!(blob.n_blocks(), 1);
        assert_eq!(blob.outpos, vec![0, 0]);
    }

    #[test]
    fn encoded_bits_matches_code_lengths() {
        let data = weight_like_bytes(10_000, 5);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        let code = blob.code();
        let expect: u64 = data
            .iter()
            .map(|&b| code.encode(Fp8Format::E4M3.split(b).0 as usize).1 as u64)
            .sum();
        assert_eq!(blob.encoded_bits, expect);
    }

    #[test]
    fn uniform_random_bytes_do_not_compress() {
        // adversarial input: uniform exponents => H(E) ~ 4 bits; ECF8
        // should report ~zero / negative saving but remain lossless
        // (losslessness is asserted in decode tests).
        let mut rng = Xoshiro256::seed_from_u64(6);
        let data: Vec<u8> = (0..100_000).map(|_| (rng.next_u64() >> 56) as u8).collect();
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        assert!(blob.memory_saving() < 0.03, "saving={}", blob.memory_saving());
    }
}
