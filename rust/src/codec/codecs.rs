//! The codec seam: a pluggable [`Codec`] trait with a registry, and the
//! [`CompressedTensor`] in-memory form that the serving stack carries.
//!
//! Container v2 stores every tensor as one record whose header names a
//! [`CodecId`]; everything between the artifact bytes and the decoded FP8
//! plane goes through this one seam instead of hardwired
//! `codec::encode`/`decode` call sites.
//!
//! Two codecs are always available:
//!
//! * [`Ecf8Huffman`] — the paper's format (§3.1): Huffman-coded exponent
//!   stream + raw sign/mantissa nibbles, block-parallel decodable;
//! * [`RawFp8`] — identity passthrough for incompressible tensors.
//!
//! [`select_codec`] is the paper's §3.2 entropy-aware encoding: each
//! candidate codec *probes* (a sample of) the tensor and predicts its
//! stored size; the smallest prediction wins. Exponent-concentrated
//! weights pick `Ecf8Huffman`; near-uniform tensors (where entropy coding
//! would pay metadata for nothing) fall back to `RawFp8`.
//!
//! With `--features ext-codecs`, the zstd/deflate baselines from
//! [`crate::baselines`] slot in behind the same trait (never chosen
//! automatically — they exist for comparisons and external artifacts).

use super::container::{self, ContainerError};
use super::decode::{self, DecodeTableCache, DecodeTables};
use super::encode;
use super::{Ecf8Blob, Ecf8Params, Fp8Format};
use crate::huffman::canonical::CanonicalCode;
use crate::util::mmap::ByteView;
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Stable codec identifiers, stored as one byte in v2 record headers and
/// index entries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CodecId {
    /// ECF8: Huffman-coded exponents + packed rest nibbles (the default).
    Ecf8Huffman = 0,
    /// Identity passthrough for incompressible tensors.
    RawFp8 = 1,
    /// zstd baseline (`ext-codecs` builds).
    Zstd = 2,
    /// DEFLATE baseline (`ext-codecs` builds).
    Deflate = 3,
}

impl CodecId {
    pub fn from_u8(v: u8) -> Option<Self> {
        match v {
            0 => Some(CodecId::Ecf8Huffman),
            1 => Some(CodecId::RawFp8),
            2 => Some(CodecId::Zstd),
            3 => Some(CodecId::Deflate),
            _ => None,
        }
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn label(self) -> &'static str {
        match self {
            CodecId::Ecf8Huffman => "ecf8-huffman",
            CodecId::RawFp8 => "raw-fp8",
            CodecId::Zstd => "zstd",
            CodecId::Deflate => "deflate",
        }
    }
}

/// Outcome of a codec's entropy probe: the predicted stored payload size
/// for a tensor, measured on (a sample of) its data without encoding it.
#[derive(Debug, Clone, Copy)]
pub struct Probe {
    pub codec: CodecId,
    pub estimated_bytes: usize,
}

/// A registered tensor codec: probe → encode → decode, all over the v2
/// record payload representation.
pub trait Codec: Send + Sync {
    fn id(&self) -> CodecId;

    /// Predict the stored payload size for `data` without encoding it.
    /// Callers probing a sample scale the estimate themselves.
    fn probe(&self, data: &[u8], format: Fp8Format) -> Probe;

    /// Compress `data` and append the record payload bytes to `out`.
    fn encode_into(&self, data: &[u8], format: Fp8Format, params: Ecf8Params, out: &mut Vec<u8>);

    /// Decode a payload produced by [`Codec::encode_into`] into `dst`
    /// (exactly the original element count).
    fn decode_into(
        &self,
        payload: &[u8],
        format: Fp8Format,
        dst: &mut [u8],
        pool: Option<&ThreadPool>,
    ) -> Result<(), ContainerError>;
}

/// The paper's format behind the trait: payload = the v1 single-blob
/// container bytes (header, streams, CRC), so a v1 `.ecf8` file body *is*
/// a valid `Ecf8Huffman` record payload — migration is a re-framing, not
/// a re-encode.
pub struct Ecf8Huffman;

impl Ecf8Huffman {
    /// [`Codec::probe`] for a specific block geometry. The per-thread
    /// gap and per-block offset metadata scale with `params`, so the
    /// prediction must use the geometry the encode will — the default
    /// 256-thread blocks are right for multi-MB weight tensors but
    /// swamp KV-block-sized payloads, where callers probe with the
    /// same small-block params they encode with.
    pub fn probe_with(&self, data: &[u8], format: Fp8Format, params: Ecf8Params) -> Probe {
        let n = data.len();
        if n == 0 {
            return Probe {
                codec: self.id(),
                estimated_bytes: container::HEADER_BYTES + format.alphabet_size() + 16,
            };
        }
        // exact code-length arithmetic, no bitstream emission: Σ count·len
        // plus the metadata the blob would carry (mirrors
        // `Ecf8Blob::compressed_bytes`)
        let hist = encode::exponent_histogram(data, format);
        let code = CanonicalCode::from_frequencies(&hist);
        let bits: u64 = hist
            .iter()
            .zip(code.lengths.iter())
            .map(|(&c, &l)| c * l as u64)
            .sum();
        let window_bits = (params.bytes_per_thread * 8) as u64;
        let n_threads_used = (bits / window_bits) as usize + 1;
        let n_blocks = n_threads_used.div_ceil(params.threads_per_block).max(1);
        let n_threads = n_blocks * params.threads_per_block;
        let estimated_bytes = (bits as usize).div_ceil(8)
            + n.div_ceil(2)
            + n_threads.div_ceil(2)
            + (n_blocks + 1) * 8
            + format.alphabet_size()
            + container::HEADER_BYTES;
        Probe {
            codec: self.id(),
            estimated_bytes,
        }
    }
}

impl Codec for Ecf8Huffman {
    fn id(&self) -> CodecId {
        CodecId::Ecf8Huffman
    }

    fn probe(&self, data: &[u8], format: Fp8Format) -> Probe {
        self.probe_with(data, format, Ecf8Params::default())
    }

    fn encode_into(&self, data: &[u8], format: Fp8Format, params: Ecf8Params, out: &mut Vec<u8>) {
        let blob = encode::encode(data, format, params);
        out.reserve(container::serialized_len(&blob));
        container::serialize_into(&blob, out).expect("Vec<u8> writes are infallible");
    }

    fn decode_into(
        &self,
        payload: &[u8],
        format: Fp8Format,
        dst: &mut [u8],
        pool: Option<&ThreadPool>,
    ) -> Result<(), ContainerError> {
        let blob = container::deserialize_owned(payload.to_vec())?;
        if blob.format != format {
            return Err(ContainerError::Inconsistent("record format vs payload"));
        }
        if blob.n_elem != dst.len() {
            return Err(ContainerError::Inconsistent("record n_elem vs payload"));
        }
        decode::decode_into(&blob, dst, pool);
        Ok(())
    }
}

/// Identity passthrough: payload = the raw FP8 bytes. Chosen by the
/// entropy probe when Huffman coding the exponents would not pay for its
/// own metadata (§3.2 "to compress or not").
pub struct RawFp8;

impl Codec for RawFp8 {
    fn id(&self) -> CodecId {
        CodecId::RawFp8
    }

    fn probe(&self, data: &[u8], _format: Fp8Format) -> Probe {
        Probe {
            codec: self.id(),
            estimated_bytes: data.len(),
        }
    }

    fn encode_into(&self, data: &[u8], _format: Fp8Format, _params: Ecf8Params, out: &mut Vec<u8>) {
        out.extend_from_slice(data);
    }

    fn decode_into(
        &self,
        payload: &[u8],
        _format: Fp8Format,
        dst: &mut [u8],
        _pool: Option<&ThreadPool>,
    ) -> Result<(), ContainerError> {
        if payload.len() != dst.len() {
            return Err(ContainerError::Inconsistent("raw payload length vs n_elem"));
        }
        dst.copy_from_slice(payload);
        Ok(())
    }
}

#[cfg(not(feature = "ext-codecs"))]
static REGISTRY: [&dyn Codec; 2] = [&Ecf8Huffman, &RawFp8];
#[cfg(feature = "ext-codecs")]
static REGISTRY: [&dyn Codec; 4] = [
    &Ecf8Huffman,
    &RawFp8,
    &crate::baselines::Zstd(3),
    &crate::baselines::Deflate(6),
];

/// Every codec this build can decode.
pub fn registry() -> &'static [&'static dyn Codec] {
    &REGISTRY
}

/// Look a codec up by id; `None` when this build doesn't carry it (e.g.
/// zstd/deflate without `--features ext-codecs`).
pub fn codec_for(id: CodecId) -> Option<&'static dyn Codec> {
    registry().iter().find(|c| c.id() == id).copied()
}

/// Elements probed per tensor by [`select_codec`]; larger tensors are
/// sampled and the estimate scaled.
pub const PROBE_SAMPLE: usize = 1 << 20;

/// §3.2 entropy-aware codec selection: probe the always-available codecs
/// on (a bounded prefix of) the tensor and pick the smallest predicted
/// stored size. Restricted to the built-ins so artifact layout never
/// depends on optional features.
pub fn select_codec(data: &[u8], format: Fp8Format) -> CodecId {
    select_codec_with(data, format, Ecf8Params::default())
}

/// [`select_codec`] for a specific ECF8 block geometry — the probe's
/// metadata prediction tracks `params`, so a payload that would lose to
/// raw under the weight-tensor default geometry can still win under the
/// small-block geometry it will actually be encoded with (KV blocks).
pub fn select_codec_with(data: &[u8], format: Fp8Format, params: Ecf8Params) -> CodecId {
    if data.is_empty() {
        return CodecId::Ecf8Huffman;
    }
    let sample = &data[..data.len().min(PROBE_SAMPLE)];
    let scale = data.len() as f64 / sample.len() as f64;
    let ecf8 = Ecf8Huffman.probe_with(sample, format, params).estimated_bytes as f64 * scale;
    let raw = RawFp8.probe(sample, format).estimated_bytes as f64 * scale;
    // ties keep the entropy coder (same preference order as before the
    // params-aware probe existed)
    if ecf8 <= raw {
        CodecId::Ecf8Huffman
    } else {
        CodecId::RawFp8
    }
}

/// Probe-and-encode straight to the in-memory serving form (no payload
/// round-trip for the built-ins). Probe and encode share `params`.
pub fn compress_auto(data: &[u8], format: Fp8Format, params: Ecf8Params) -> CompressedTensor {
    match select_codec_with(data, format, params) {
        CodecId::Ecf8Huffman => CompressedTensor::Ecf8(encode::encode(data, format, params)),
        CodecId::RawFp8 => CompressedTensor::Raw(RawTensor {
            format,
            bytes: data.to_vec().into(),
        }),
        other => unreachable!("auto-selection is restricted to built-ins, got {other:?}"),
    }
}

/// Raw FP8 passthrough tensor (the [`RawFp8`] codec's parsed form). The
/// bytes are a [`ByteView`]: a window into the mapped shard on the
/// zero-copy load path, an owned buffer otherwise.
#[derive(Debug, Clone)]
pub struct RawTensor {
    pub format: Fp8Format,
    pub bytes: ByteView,
}

/// A payload held for a registry codec outside the built-ins (zstd /
/// deflate baselines); decoded through the registry on demand.
#[derive(Debug, Clone)]
pub struct ExternalTensor {
    pub codec: CodecId,
    pub format: Fp8Format,
    pub n_elem: usize,
    pub payload: ByteView,
}

/// An in-memory compressed tensor behind the codec seam — the parsed
/// serving form of one container-v2 record. This is what
/// [`crate::model::store::CompressedModel`] holds and what the JIT /
/// decode-stage paths consume.
#[derive(Debug, Clone)]
pub enum CompressedTensor {
    Ecf8(Ecf8Blob),
    Raw(RawTensor),
    External(ExternalTensor),
}

impl CompressedTensor {
    pub fn codec_id(&self) -> CodecId {
        match self {
            CompressedTensor::Ecf8(_) => CodecId::Ecf8Huffman,
            CompressedTensor::Raw(_) => CodecId::RawFp8,
            CompressedTensor::External(e) => e.codec,
        }
    }

    pub fn n_elem(&self) -> usize {
        match self {
            CompressedTensor::Ecf8(b) => b.n_elem,
            CompressedTensor::Raw(r) => r.bytes.len(),
            CompressedTensor::External(e) => e.n_elem,
        }
    }

    pub fn format(&self) -> Fp8Format {
        match self {
            CompressedTensor::Ecf8(b) => b.format,
            CompressedTensor::Raw(r) => r.format,
            CompressedTensor::External(e) => e.format,
        }
    }

    /// Stored size in bytes (payload + per-record metadata) — the Table 1
    /// "Memory (GB)" accounting, codec-generic.
    pub fn compressed_bytes(&self) -> usize {
        match self {
            CompressedTensor::Ecf8(b) => b.compressed_bytes(),
            CompressedTensor::Raw(r) => r.bytes.len() + container::RECORD_HEADER_BYTES,
            CompressedTensor::External(e) => e.payload.len() + container::RECORD_HEADER_BYTES,
        }
    }

    /// Fraction of memory saved vs. raw FP8.
    pub fn memory_saving(&self) -> f64 {
        1.0 - self.compressed_bytes() as f64 / self.n_elem() as f64
    }

    pub fn as_ecf8(&self) -> Option<&Ecf8Blob> {
        match self {
            CompressedTensor::Ecf8(b) => Some(b),
            _ => None,
        }
    }

    /// Decode tiers for this tensor's code book, when it has one (only
    /// the ECF8 path uses LUTs; passthrough needs none).
    pub fn tables(&self, cache: &mut DecodeTableCache) -> Option<Arc<DecodeTables>> {
        self.as_ecf8().map(|b| cache.get_or_build(b))
    }

    /// Exact length [`Self::payload_bytes`] will produce, without
    /// serializing anything.
    pub fn payload_len(&self) -> usize {
        match self {
            CompressedTensor::Ecf8(b) => container::serialized_len(b),
            CompressedTensor::Raw(r) => r.bytes.len(),
            CompressedTensor::External(e) => e.payload.len(),
        }
    }

    /// Serialize to the v2 record payload for this tensor's codec.
    pub fn payload_bytes(&self) -> Vec<u8> {
        match self {
            CompressedTensor::Ecf8(b) => container::serialize(b),
            CompressedTensor::Raw(r) => r.bytes.to_vec(),
            CompressedTensor::External(e) => e.payload.to_vec(),
        }
    }

    /// True when every payload byte of this tensor lives in a real file
    /// mapping (the zero-copy load path; always false for encoder-built
    /// tensors and on the read-copy tier).
    pub fn payload_is_mapped(&self) -> bool {
        match self {
            CompressedTensor::Ecf8(b) => {
                b.encoded.is_mapped() && b.packed.is_mapped() && b.gaps.is_mapped()
            }
            CompressedTensor::Raw(r) => r.bytes.is_mapped(),
            CompressedTensor::External(e) => e.payload.is_mapped(),
        }
    }

    /// Decode into `dst` (must be exactly [`Self::n_elem`] bytes).
    pub fn decode_into(&self, dst: &mut [u8], pool: Option<&ThreadPool>) {
        self.decode_into_cached(dst, pool, None)
    }

    /// [`Self::decode_into`] with optionally prebuilt [`DecodeTables`]
    /// (the hot serving entry point — no per-call LUT construction).
    pub fn decode_into_cached(
        &self,
        dst: &mut [u8],
        pool: Option<&ThreadPool>,
        tables: Option<&DecodeTables>,
    ) {
        assert_eq!(dst.len(), self.n_elem(), "output buffer size mismatch");
        match self {
            CompressedTensor::Ecf8(b) => match tables {
                Some(t) => decode::decode_into_cached(b, dst, pool, t),
                None => decode::decode_into(b, dst, pool),
            },
            CompressedTensor::Raw(r) => dst.copy_from_slice(&r.bytes),
            CompressedTensor::External(e) => {
                codec_for(e.codec)
                    .expect("external codec availability checked at parse")
                    .decode_into(&e.payload, e.format, dst, pool)
                    .expect("external payload decode-validated at parse");
            }
        }
    }

    /// Decode into a fresh buffer.
    pub fn decode_to_vec(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.n_elem()];
        self.decode_into(&mut out, None);
        out
    }
}

/// Parse a CRC-verified v2 record payload into its in-memory serving
/// form. `codec`/`format` are the record-header bytes; `n_elem` the
/// header's element count (cross-checked against the payload). Copies
/// the payload once; the load paths hold a [`ByteView`] already and use
/// [`parse_record_view`], which copies nothing.
pub fn parse_record(
    codec: u8,
    format: u8,
    n_elem: usize,
    payload: &[u8],
) -> Result<CompressedTensor, ContainerError> {
    parse_record_view(codec, format, n_elem, ByteView::from_vec(payload.to_vec()))
}

/// Zero-copy [`parse_record`]: the parsed tensor's payload bytes share
/// `payload`'s backing, so a tensor from a mapped shard serves straight
/// out of the page cache.
pub fn parse_record_view(
    codec: u8,
    format: u8,
    n_elem: usize,
    payload: ByteView,
) -> Result<CompressedTensor, ContainerError> {
    let codec = CodecId::from_u8(codec).ok_or(ContainerError::Inconsistent("unknown codec id"))?;
    let format = Fp8Format::from_u8(format).ok_or(ContainerError::BadFormat(format))?;
    match codec {
        CodecId::Ecf8Huffman => {
            let blob = container::deserialize_view(&payload)?;
            if blob.n_elem != n_elem || blob.format != format {
                return Err(ContainerError::Inconsistent("record metadata vs payload"));
            }
            Ok(CompressedTensor::Ecf8(blob))
        }
        CodecId::RawFp8 => {
            if payload.len() != n_elem {
                return Err(ContainerError::Inconsistent("raw payload length vs n_elem"));
            }
            Ok(CompressedTensor::Raw(RawTensor {
                format,
                bytes: payload,
            }))
        }
        other => {
            let codec = codec_for(other).ok_or_else(|| {
                ContainerError::Inconsistent("codec unavailable (enable ext-codecs)")
            })?;
            // external payloads carry no internal consistency structure of
            // their own (unlike ECF8 blobs), so validate by trial decode
            // here — the serving decode paths cannot surface errors
            let mut scratch = vec![0u8; n_elem];
            codec.decode_into(&payload, format, &mut scratch, None)?;
            Ok(CompressedTensor::External(ExternalTensor {
                codec: other,
                format,
                n_elem,
                payload,
            }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn weight_like(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                let x = (crate::util::sampling::normal(&mut rng) * 0.05) as f32;
                crate::fp8::F8E4M3::from_f32(x).to_bits()
            })
            .collect()
    }

    fn noise(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n).map(|_| (rng.next_u64() >> 56) as u8).collect()
    }

    #[test]
    fn registry_has_builtins() {
        assert!(codec_for(CodecId::Ecf8Huffman).is_some());
        assert!(codec_for(CodecId::RawFp8).is_some());
        for c in registry() {
            assert_eq!(CodecId::from_u8(c.id().as_u8()), Some(c.id()));
        }
    }

    #[test]
    fn every_registered_codec_roundtrips() {
        for data in [weight_like(20_000, 1), noise(20_000, 2), Vec::new()] {
            for codec in registry() {
                let mut payload = Vec::new();
                codec.encode_into(&data, Fp8Format::E4M3, Ecf8Params::default(), &mut payload);
                let mut out = vec![0u8; data.len()];
                codec
                    .decode_into(&payload, Fp8Format::E4M3, &mut out, None)
                    .unwrap();
                assert_eq!(out, data, "{}", codec.id().label());
            }
        }
    }

    #[test]
    fn probe_estimates_track_actual_sizes() {
        let data = weight_like(100_000, 3);
        for codec in [&Ecf8Huffman as &dyn Codec, &RawFp8] {
            let est = codec.probe(&data, Fp8Format::E4M3).estimated_bytes;
            let mut payload = Vec::new();
            codec.encode_into(&data, Fp8Format::E4M3, Ecf8Params::default(), &mut payload);
            let rel = (est as f64 - payload.len() as f64).abs() / payload.len() as f64;
            assert!(rel < 0.05, "{}: est {est} vs actual {}", codec.id().label(), payload.len());
        }
    }

    #[test]
    fn entropy_probe_selects_ecf8_for_weights_and_raw_for_noise() {
        assert_eq!(
            select_codec(&weight_like(50_000, 4), Fp8Format::E4M3),
            CodecId::Ecf8Huffman
        );
        assert_eq!(
            select_codec(&noise(50_000, 5), Fp8Format::E4M3),
            CodecId::RawFp8
        );
    }

    #[test]
    fn params_aware_probe_rescues_small_blocks() {
        // exponent-concentrated payloads at KV-block scale (uniform
        // ±0.05 magnitudes — the KV substitution's weight lane; three
        // exponent fields, H(E) ≈ 1.6 bits)
        let kv_params = Ecf8Params {
            threads_per_block: 8,
            bytes_per_thread: 8,
        };
        let gen = |n: usize, seed: u64| -> Vec<u8> {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            (0..n)
                .map(|_| {
                    let x = (rng.next_f32() - 0.5) * 0.1;
                    crate::fp8::F8E4M3::from_f32(x).to_bits()
                })
                .collect()
        };
        // 640 B: the default 256-thread geometry's gap metadata alone
        // (128 B) sinks it; the small-block geometry it would actually
        // be encoded with keeps the entropy coder in play
        let small = gen(640, 20);
        assert_eq!(select_codec(&small, Fp8Format::E4M3), CodecId::RawFp8);
        assert_eq!(
            select_codec_with(&small, Fp8Format::E4M3, kv_params),
            CodecId::Ecf8Huffman
        );
        // 2 KiB: the win is real in *stored* bytes too (the probe's
        // unpadded accounting intentionally ignores block padding, so
        // verify against the actual serialized payload at a size where
        // padding cannot flip the outcome)
        let block = gen(2048, 21);
        let est = Ecf8Huffman
            .probe_with(&block, Fp8Format::E4M3, kv_params)
            .estimated_bytes;
        let mut payload = Vec::new();
        Ecf8Huffman.encode_into(&block, Fp8Format::E4M3, kv_params, &mut payload);
        assert!(payload.len() < block.len(), "kv-geometry ecf8 actually wins");
        let rel = (est as f64 - payload.len() as f64).abs() / payload.len() as f64;
        assert!(rel < 0.08, "est {est} vs actual {}", payload.len());
        // and compress_auto with those params produces a decodable win
        let t = compress_auto(&block, Fp8Format::E4M3, kv_params);
        assert_eq!(t.codec_id(), CodecId::Ecf8Huffman);
        assert_eq!(t.decode_to_vec(), block);
    }

    #[test]
    fn compress_auto_matches_direct_encode_for_weights() {
        let data = weight_like(30_000, 6);
        let auto = compress_auto(&data, Fp8Format::E4M3, Ecf8Params::default());
        let direct = encode::encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        let blob = auto.as_ecf8().expect("weights pick ecf8");
        assert_eq!(blob.encoded, direct.encoded);
        assert_eq!(blob.packed, direct.packed);
        assert_eq!(auto.decode_to_vec(), data);
        assert!(auto.memory_saving() > 0.05);
    }

    #[test]
    fn compress_auto_noise_is_raw_and_lossless() {
        let data = noise(10_000, 7);
        let t = compress_auto(&data, Fp8Format::E4M3, Ecf8Params::default());
        assert_eq!(t.codec_id(), CodecId::RawFp8);
        assert_eq!(t.n_elem(), data.len());
        assert_eq!(t.decode_to_vec(), data);
        // passthrough pays only the record header
        assert_eq!(t.compressed_bytes(), data.len() + container::RECORD_HEADER_BYTES);
    }

    #[test]
    fn payload_roundtrips_through_parse_record() {
        for data in [weight_like(8_192, 8), noise(8_192, 9)] {
            let t = compress_auto(&data, Fp8Format::E4M3, Ecf8Params::default());
            let payload = t.payload_bytes();
            assert_eq!(t.payload_len(), payload.len());
            let back = parse_record(
                t.codec_id().as_u8(),
                t.format() as u8,
                t.n_elem(),
                &payload,
            )
            .unwrap();
            assert_eq!(back.codec_id(), t.codec_id());
            assert_eq!(back.decode_to_vec(), data);
        }
    }

    #[test]
    fn parse_record_rejects_mismatches() {
        let data = weight_like(1000, 10);
        let t = compress_auto(&data, Fp8Format::E4M3, Ecf8Params::default());
        let payload = t.payload_bytes();
        // wrong n_elem
        assert!(parse_record(0, 0, 999, &payload).is_err());
        // unknown codec id
        assert!(parse_record(200, 0, 1000, &payload).is_err());
        // raw payload of the wrong length
        assert!(parse_record(1, 0, 7, b"too long for seven").is_err());
    }

    #[test]
    fn tables_only_built_for_ecf8() {
        let mut cache = DecodeTableCache::new();
        let w = compress_auto(&weight_like(5_000, 11), Fp8Format::E4M3, Ecf8Params::default());
        let r = compress_auto(&noise(5_000, 12), Fp8Format::E4M3, Ecf8Params::default());
        assert!(w.tables(&mut cache).is_some());
        assert!(r.tables(&mut cache).is_none());
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn cached_decode_matches_uncached() {
        let data = weight_like(40_000, 13);
        let t = compress_auto(&data, Fp8Format::E4M3, Ecf8Params::default());
        let mut cache = DecodeTableCache::new();
        let tables = t.tables(&mut cache).unwrap();
        let mut a = vec![0u8; data.len()];
        let mut b = vec![0u8; data.len()];
        t.decode_into(&mut a, None);
        t.decode_into_cached(&mut b, None, Some(&tables));
        assert_eq!(a, data);
        assert_eq!(b, data);
    }
}
