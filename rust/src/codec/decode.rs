//! ECF8 block-parallel decoder — Algorithm 1 (§3.2).
//!
//! Three paths, all bit-exact:
//!
//! * [`decode_block_alg1`] — the faithful reproduction of Algorithm 1: per
//!   simulated thread, a 64-bit sliding window `L`, 16-bit tail `S`,
//!   headroom counter `f`; phase 1 counts symbols, an in-block exclusive
//!   prefix sum assigns output slots, phase 2 decodes and assembles FP8
//!   bytes. Each thread consumes exactly its `B`-byte window (plus ≤ 2
//!   lookahead bytes), coordinated purely by the gap/outpos metadata — no
//!   cross-thread communication, exactly as on the GPU.
//! * [`decode_block_fast`] — the CPU-tuned path: one sequential sweep per
//!   block using unaligned u64 loads (a CPU "thread" is the paper's
//!   thread *block*; the per-thread machinery exists for intra-block SIMT
//!   parallelism we don't have). Used by default.
//! * [`decode_scalar_reference`] — whole-stream scalar decode via the
//!   slow prefix-matching `CanonicalCode::decode_window`, ground truth in
//!   tests.
//!
//! The public entry point [`decode_into`] fans blocks out over a thread
//! pool; blocks write disjoint output slices (`outpos[b] .. outpos[b+1]`).

use super::{Ecf8Blob, Fp8Format};
use crate::huffman::bitstream::BitReader;
use crate::huffman::lut::DecodeLut;
use crate::util::threadpool::ThreadPool;

/// Which decode implementation to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodePath {
    /// CPU-tuned single sweep per block with pair-LUT dispatch (default).
    #[default]
    Fast,
    /// Fast sweep without the pair LUT (ablation).
    FastSingle,
    /// Faithful Algorithm-1 per-thread two-phase simulation.
    Alg1,
}

/// Decode the whole blob into `out` (must be exactly `n_elem` bytes).
/// `pool`: optional thread pool for block parallelism; `None` = serial.
pub fn decode_into(blob: &Ecf8Blob, out: &mut [u8], pool: Option<&ThreadPool>) {
    decode_into_path(blob, out, pool, DecodePath::Fast)
}

/// Decode with an explicit implementation choice (benches/tests).
pub fn decode_into_path(
    blob: &Ecf8Blob,
    out: &mut [u8],
    pool: Option<&ThreadPool>,
    path: DecodePath,
) {
    assert_eq!(out.len(), blob.n_elem, "output buffer size mismatch");
    let lut = blob.lut();
    let pair = match path {
        DecodePath::Fast => Some(crate::huffman::lut::PairLut::build(&lut)),
        _ => None,
    };
    let n_blocks = blob.n_blocks();

    // Blocks own disjoint output ranges outpos[b]..outpos[b+1]; hand each
    // worker the output base address and rely on that disjointness (same
    // contract as the CUDA kernel's non-overlapping shared-memory slices).
    let out_addr = out.as_mut_ptr() as usize;
    let out_len = out.len();

    let run_block = |b: usize| {
        let lo = blob.outpos[b] as usize;
        let hi = blob.outpos[b + 1] as usize;
        debug_assert!(lo <= hi && hi <= out_len);
        // SAFETY: [lo, hi) ranges are disjoint across blocks and in-bounds.
        let slice =
            unsafe { std::slice::from_raw_parts_mut((out_addr as *mut u8).add(lo), hi - lo) };
        match path {
            DecodePath::Fast => {
                decode_block_fast_pair(blob, &lut, pair.as_ref().unwrap(), b, slice)
            }
            DecodePath::FastSingle => decode_block_fast(blob, &lut, b, slice),
            DecodePath::Alg1 => decode_block_alg1(blob, &lut, b, slice),
        }
    };

    match pool {
        Some(pool) => pool.scope_chunks(n_blocks, pool.size() * 4, |_, s, e| {
            for b in s..e {
                run_block(b);
            }
        }),
        None => {
            for b in 0..n_blocks {
                run_block(b);
            }
        }
    }
}

/// Extract thread `t_g`'s 4-bit gap (Algorithm 1 line 5).
#[inline(always)]
fn gap_of(gaps: &[u8], t_g: usize) -> u32 {
    ((gaps[t_g / 2] >> (4 - (t_g % 2) * 4)) & 0x0F) as u32
}

/// Extract the rest nibble of output element `o` (Algorithm 1 line 23).
#[inline(always)]
fn rest_of(packed: &[u8], o: usize) -> u8 {
    (packed[o / 2] >> (4 - (o % 2) * 4)) & 0x0F
}

// ---------------------------------------------------------------------------
// Faithful Algorithm-1 path
// ---------------------------------------------------------------------------

/// Decode block `b` exactly as Algorithm 1: two phases over T simulated
/// threads with an exclusive prefix sum between them. `out_block` is the
/// block's disjoint output slice (`outpos[b]..outpos[b+1]`).
pub fn decode_block_alg1(blob: &Ecf8Blob, lut: &DecodeLut, b: usize, out_block: &mut [u8]) {
    let t_per_block = blob.params.threads_per_block;
    let b_bytes = blob.params.bytes_per_thread;
    let window_bits = (b_bytes * 8) as u32;
    let o_base = blob.outpos[b] as usize;
    let o_block_end = blob.outpos[b + 1] as usize;
    let n_elem = blob.n_elem;
    if o_base == o_block_end {
        // nothing to produce (empty tensor); the padding windows would
        // only count garbage
        return;
    }

    // ---- Phase 1: per-thread symbol counting (lines 6–15) ----
    let mut counts = vec![0u32; t_per_block];
    for t in 0..t_per_block {
        let t_g = b * t_per_block + t;
        let byte_off = t_g * b_bytes;
        let gap = gap_of(&blob.gaps, t_g);
        // bits available to *start* a codeword in this window
        let mut consumed = gap;
        let mut lr = WindowReader::new(&blob.encoded, byte_off, b_bytes, gap);
        let mut c = 0u32;
        while consumed < window_bits {
            let (_, len) = lut.decode(lr.peek16());
            if len == 0 {
                // unreachable with a complete code; reachable only in
                // zero-padding under a degenerate (single-symbol) book
                break;
            }
            lr.skip(len);
            consumed += len;
            c += 1;
        }
        counts[t] = c;
    }

    // ---- Block-level exclusive prefix sum (lines 16–19) ----
    // accum[t] = outpos[b] + sum counts[0..t]; accum[T] forced to
    // outpos[b+1] (the metadata bound wins over padding overcount).
    let mut accum = vec![0usize; t_per_block + 1];
    accum[0] = o_base;
    for t in 0..t_per_block {
        accum[t + 1] = accum[t] + counts[t] as usize;
    }
    accum[t_per_block] = o_block_end;

    // ---- Phase 2: decode and assemble FP8 (lines 20–31) ----
    let format = blob.format;
    for t in 0..t_per_block {
        let t_g = b * t_per_block + t;
        let byte_off = t_g * b_bytes;
        let gap = gap_of(&blob.gaps, t_g);
        let o_start = accum[t];
        let o_end = (accum[t] + counts[t] as usize)
            .min(n_elem)
            .min(o_block_end);
        let mut lr = WindowReader::new(&blob.encoded, byte_off, b_bytes, gap);
        let mut o = o_start;
        while o < o_end {
            let (x, len) = lut.decode(lr.peek16());
            lr.skip(len);
            let rest = rest_of(&blob.packed, o);
            out_block[o - o_base] = format.assemble(x as u8, rest);
            o += 1;
        }
    }
}

/// The 80-bit (head+tail) register window of Algorithm 1, expressed as a
/// safe reader: `peek16`/`skip` over the thread's B+2 loaded bytes. The
/// arithmetic mirrors lines 4–12: a u64 head `L`, u16 tail `S`, stitch at
/// 16 remaining bits.
struct WindowReader {
    l: u64,
    s: u16,
    /// bits consumed so far (including the initial gap)
    f: u32,
    stitched: bool,
}

impl WindowReader {
    #[inline(always)]
    fn new(encoded: &[u8], byte_off: usize, b_bytes: usize, gap: u32) -> Self {
        // Supported geometries: B = 8 (the faithful 64-bit head + 16-bit
        // tail) or B <= 6 (the 8-byte head already covers B+2 bytes, so
        // the worst-case read 8B-1+16 <= 63 bits never leaves the head).
        debug_assert!(
            b_bytes == 8 || b_bytes <= 6,
            "bytes_per_thread must be 8 or <= 6 (got {b_bytes})"
        );
        let mut head = [0u8; 8];
        head[..8].copy_from_slice(&encoded[byte_off..byte_off + 8]);
        let l = u64::from_be_bytes(head);
        let s = u16::from_be_bytes([encoded[byte_off + b_bytes], encoded[byte_off + b_bytes + 1]]);
        let mut r = Self {
            l,
            s,
            f: 0,
            // For B < 8 the tail bytes are already inside the head load.
            stitched: b_bytes < 8,
        };
        r.skip_raw(gap);
        r
    }

    #[inline(always)]
    fn peek16(&self) -> u16 {
        (self.l >> 48) as u16
    }

    #[inline(always)]
    fn skip_raw(&mut self, bits: u32) {
        self.l <<= bits;
        self.f += bits;
        if !self.stitched && self.f > 48 {
            // fewer than 16 valid head bits remain: stitch the tail in at
            // its correct position (Alg. 1 lines 12 / 28:
            // L |= S << (f - 16) — in our orientation the tail lands
            // `64 - (80 - f)` bits from the top).
            self.l |= (self.s as u64) << self.f.saturating_sub(16).min(48);
            self.stitched = true;
        }
    }

    #[inline(always)]
    fn skip(&mut self, bits: u32) {
        self.skip_raw(bits);
    }
}

// ---------------------------------------------------------------------------
// CPU fast path
// ---------------------------------------------------------------------------

/// Decode block `b` in one sequential sweep with unaligned u64 refills
/// and pair-LUT dispatch (two symbols per lookup where the pair table
/// covers — see [`crate::huffman::lut::PairLut`]).
pub fn decode_block_fast_pair(
    blob: &Ecf8Blob,
    lut: &DecodeLut,
    pair: &crate::huffman::lut::PairLut,
    b: usize,
    out_block: &mut [u8],
) {
    let block_bytes = blob.params.block_bytes();
    let start_byte = b * block_bytes;
    let t0 = b * blob.params.threads_per_block;
    let gap = gap_of(&blob.gaps, t0) as u64;
    let o_base = blob.outpos[b] as usize;
    let o_end = blob.outpos[b + 1] as usize;
    let n = o_end - o_base;
    if n == 0 {
        return;
    }
    let enc = &blob.encoded;
    let packed = &blob.packed;
    let format = blob.format;
    let mut bitpos = (start_byte as u64) * 8 + gap;
    let mut o = 0usize;

    macro_rules! sweep {
        ($assemble:expr) => {{
            while o < n {
                let byte = (bitpos >> 3) as usize;
                let sh = (bitpos & 7) as u32;
                let w0 = u64::from_be_bytes(enc[byte..byte + 8].try_into().unwrap());
                let mut w = w0 << sh;
                let mut avail = 64 - sh;
                loop {
                    // pair fast path: needs 2 output slots and >= 12 bits
                    if o + 2 <= n && avail >= 12 {
                        if let Some((x1, x2, len)) = pair.decode_pair(w) {
                            w <<= len;
                            avail -= len;
                            bitpos += len as u64;
                            let oo = o_base + o;
                            // both rest nibbles in one load when aligned
                            let (r1, r2) = if oo & 1 == 0 {
                                let pb = packed[oo >> 1];
                                (pb >> 4, pb & 0x0F)
                            } else {
                                (packed[oo >> 1] & 0x0F, packed[(oo >> 1) + 1] >> 4)
                            };
                            out_block[o] = $assemble(x1, r1);
                            out_block[o + 1] = $assemble(x2, r2);
                            o += 2;
                            if o == n || avail < 16 {
                                break;
                            }
                            continue;
                        }
                    }
                    if avail < 16 {
                        break;
                    }
                    let (x, len) = lut.decode((w >> 48) as u16);
                    w <<= len;
                    avail -= len;
                    bitpos += len as u64;
                    let oo = o_base + o;
                    let rest = (packed[oo / 2] >> (4 - (oo % 2) * 4)) & 0x0F;
                    out_block[o] = $assemble(x as u8, rest);
                    o += 1;
                    if o == n || avail < 16 {
                        break;
                    }
                }
            }
        }};
    }

    match format {
        Fp8Format::E4M3 => {
            sweep!(|x: u8, rest: u8| ((rest & 0x08) << 4) | (x << 3) | (rest & 0x07))
        }
        Fp8Format::E5M2 => {
            sweep!(|x: u8, rest: u8| ((rest & 0x04) << 5) | (x << 2) | (rest & 0x03))
        }
    }
}

/// Decode block `b` in one sequential sweep with unaligned u64 refills.
pub fn decode_block_fast(blob: &Ecf8Blob, lut: &DecodeLut, b: usize, out_block: &mut [u8]) {
    let block_bytes = blob.params.block_bytes();
    let start_byte = b * block_bytes;
    let t0 = b * blob.params.threads_per_block;
    let gap = gap_of(&blob.gaps, t0) as u64;
    let o_base = blob.outpos[b] as usize;
    let o_end = blob.outpos[b + 1] as usize;
    let n = o_end - o_base;
    if n == 0 {
        return;
    }

    let enc = &blob.encoded;
    let packed = &blob.packed;
    let format = blob.format;
    let mut bitpos = (start_byte as u64) * 8 + gap;
    let mut o = 0usize;

    // Assemble format constants outside the loop; E4M3 dominates, keep the
    // match out of the hot loop by monomorphising per format.
    macro_rules! sweep {
        ($assemble:expr) => {{
            while o < n {
                // refill: 64-bit window starting at bitpos (encoded has
                // >= 8 bytes of zero slack past every block)
                let byte = (bitpos >> 3) as usize;
                let sh = (bitpos & 7) as u32;
                let w0 = u64::from_be_bytes(enc[byte..byte + 8].try_into().unwrap());
                let mut w = w0 << sh;
                let mut avail = 64 - sh;
                loop {
                    let (x, len) = lut.decode((w >> 48) as u16);
                    w <<= len;
                    avail -= len;
                    bitpos += len as u64;
                    let oo = o_base + o;
                    let rest = (packed[oo / 2] >> (4 - (oo % 2) * 4)) & 0x0F;
                    out_block[o] = $assemble(x as u8, rest);
                    o += 1;
                    if o == n {
                        break;
                    }
                    if avail < 16 {
                        break;
                    }
                }
            }
        }};
    }

    match format {
        Fp8Format::E4M3 => {
            sweep!(|x: u8, rest: u8| ((rest & 0x08) << 4) | (x << 3) | (rest & 0x07))
        }
        Fp8Format::E5M2 => {
            sweep!(|x: u8, rest: u8| ((rest & 0x04) << 5) | (x << 2) | (rest & 0x03))
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar reference
// ---------------------------------------------------------------------------

/// Ground-truth decoder: sequential prefix-match over the whole stream.
pub fn decode_scalar_reference(blob: &Ecf8Blob) -> Vec<u8> {
    let code = blob.code();
    let mut out = vec![0u8; blob.n_elem];
    let mut reader = BitReader::new(&blob.encoded);
    for (o, slot) in out.iter_mut().enumerate() {
        let window = reader.peek16();
        let (sym, len) = code
            .decode_window(window)
            .expect("valid stream decodes a symbol");
        reader.skip(len);
        let rest = rest_of(&blob.packed, o);
        *slot = blob.format.assemble(sym as u8, rest);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::encode::encode;
    use crate::codec::{Ecf8Params, Fp8Format};
    use crate::util::prng::Xoshiro256;
    use crate::util::quickprop::{property, Gen};

    fn weight_bytes(n: usize, seed: u64, scale: f64) -> Vec<u8> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                let x = (crate::util::sampling::normal(&mut rng) * scale) as f32;
                crate::fp8::F8E4M3::from_f32(x).to_bits()
            })
            .collect()
    }

    fn roundtrip(data: &[u8], fmt: Fp8Format, params: Ecf8Params, path: DecodePath) {
        let blob = encode(data, fmt, params);
        let mut out = vec![0u8; data.len()];
        decode_into_path(&blob, &mut out, None, path);
        assert_eq!(out, data, "path {path:?} params {params:?}");
    }

    #[test]
    fn fast_path_bit_exact_small() {
        for n in [0usize, 1, 2, 3, 7, 255, 256, 1000] {
            let data = weight_bytes(n, n as u64 + 1, 0.05);
            roundtrip(&data, Fp8Format::E4M3, Ecf8Params::default(), DecodePath::Fast);
        }
    }

    #[test]
    fn alg1_path_bit_exact_small() {
        for n in [0usize, 1, 5, 100, 2048, 10_000] {
            let data = weight_bytes(n, n as u64 + 10, 0.05);
            roundtrip(&data, Fp8Format::E4M3, Ecf8Params::default(), DecodePath::Alg1);
        }
    }

    #[test]
    fn both_paths_bit_exact_multi_block() {
        // > 1 block with default geometry requires > 2048 encoded bytes
        let data = weight_bytes(200_000, 42, 0.02);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        assert!(blob.n_blocks() > 1, "want multi-block, got {}", blob.n_blocks());
        for path in [DecodePath::Fast, DecodePath::Alg1] {
            let mut out = vec![0u8; data.len()];
            decode_into_path(&blob, &mut out, None, path);
            assert_eq!(out, data, "{path:?}");
        }
    }

    #[test]
    fn parallel_decode_matches_serial() {
        let pool = ThreadPool::new(4);
        let data = weight_bytes(500_000, 7, 0.05);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        let mut a = vec![0u8; data.len()];
        let mut b = vec![0u8; data.len()];
        decode_into(&blob, &mut a, Some(&pool));
        decode_into(&blob, &mut b, None);
        assert_eq!(a, b);
        assert_eq!(a, data);
    }

    #[test]
    fn scalar_reference_agrees() {
        let data = weight_bytes(30_000, 8, 0.1);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        assert_eq!(decode_scalar_reference(&blob), data);
    }

    #[test]
    fn e5m2_roundtrip() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let data: Vec<u8> = (0..50_000)
            .map(|_| {
                let x = (crate::util::sampling::normal(&mut rng) * 0.05) as f32;
                crate::fp8::F8E5M2::from_f32(x).to_bits()
            })
            .collect();
        for path in [DecodePath::Fast, DecodePath::Alg1] {
            roundtrip(&data, Fp8Format::E5M2, Ecf8Params::default(), path);
        }
    }

    #[test]
    fn nonstandard_geometry_roundtrips() {
        // smaller threads-per-block and bytes-per-thread stress the gap /
        // outpos bookkeeping
        for (bt, tpb) in [(8usize, 32usize), (8, 1), (8, 1024), (4, 64), (6, 16)] {
            let params = Ecf8Params {
                bytes_per_thread: bt,
                threads_per_block: tpb,
            };
            let data = weight_bytes(60_000, (bt * tpb) as u64, 0.05);
            roundtrip(&data, Fp8Format::E4M3, params, DecodePath::Fast);
            roundtrip(&data, Fp8Format::E4M3, params, DecodePath::Alg1);
        }
    }

    #[test]
    fn adversarial_uniform_bytes_roundtrip() {
        let mut rng = Xoshiro256::seed_from_u64(10);
        let data: Vec<u8> = (0..123_457).map(|_| (rng.next_u64() >> 56) as u8).collect();
        for path in [DecodePath::Fast, DecodePath::Alg1] {
            roundtrip(&data, Fp8Format::E4M3, Ecf8Params::default(), path);
        }
    }

    #[test]
    fn all_same_exponent_roundtrip() {
        // degenerate single-symbol alphabet: code length forced to 1
        let data = vec![0x38u8; 10_000]; // 1.0 repeated
        for path in [DecodePath::Fast, DecodePath::Alg1] {
            roundtrip(&data, Fp8Format::E4M3, Ecf8Params::default(), path);
        }
    }

    #[test]
    fn property_roundtrip_random_tensors() {
        property("ecf8 roundtrip on arbitrary byte tensors", 60, |g: &mut Gen| {
            let n = g.usize_in(0..=8192);
            let data: Vec<u8> = (0..n).map(|_| g.u8()).collect();
            let params = *g.choose(&[
                Ecf8Params::default(),
                Ecf8Params {
                    bytes_per_thread: 8,
                    threads_per_block: 32,
                },
                Ecf8Params {
                    bytes_per_thread: 4,
                    threads_per_block: 128,
                },
            ]);
            let fmt = *g.choose(&[Fp8Format::E4M3, Fp8Format::E5M2]);
            let blob = encode(&data, fmt, params);
            let mut out = vec![0u8; n];
            let path = if g.bool() { DecodePath::Fast } else { DecodePath::Alg1 };
            decode_into_path(&blob, &mut out, None, path);
            assert_eq!(out, data);
        });
    }

    #[test]
    fn property_weightlike_heavy_tail_roundtrip() {
        property("ecf8 roundtrip on weight-like tensors", 40, |g: &mut Gen| {
            let ws = g.vec_weights(1..=4096);
            let data: Vec<u8> = ws
                .iter()
                .map(|&w| crate::fp8::F8E4M3::from_f32(w).to_bits())
                .collect();
            let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
            let mut out = vec![0u8; data.len()];
            decode_into(&blob, &mut out, None);
            assert_eq!(out, data);
        });
    }
}
