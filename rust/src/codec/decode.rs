//! ECF8 block-parallel decoder — Algorithm 1 (§3.2).
//!
//! ## Decode paths
//!
//! Four paths, all bit-exact against [`decode_scalar_reference`]:
//!
//! * [`DecodePath::Fast`] (default) — the multi-symbol throughput engine:
//!   a branchless carry-forward bit reader ([`BitCursor`]) feeding a
//!   14-bit [`MultiLut`] that emits up to 4 symbols per lookup, with
//!   sign/mantissa nibbles streamed through a second cursor over the
//!   packed nibble plane (u64 loads, 8 nibbles each) and exponent/nibble
//!   reassembly vectorized by the [`simd`] tier (SSE2/NEON/SWAR — up to
//!   16 output bytes per store, four lookups per bit refill).
//! * [`DecodePath::FastPair`] — the previous-generation pair-LUT sweep
//!   (2 symbols/lookup, reload-per-refill), kept as an ablation tier.
//! * [`DecodePath::FastSingle`] — single-symbol LUT sweep (ablation).
//! * [`DecodePath::Alg1`] — the faithful Algorithm-1 per-thread two-phase
//!   simulation (64-bit window `L`, 16-bit tail `S`, prefix-sum slot
//!   assignment), exactly the GPU kernel's structure.
//!
//! ## Tier dispatch (Fast path)
//!
//! ```text
//!             ┌─ refill: avail ≥ 56 live bits in register ─┐
//!   window ──▶│ MultiLut[top 14 bits]                      │
//!             │   count = 4 ──▶ emit 4 syms + 4 nibbles    │ ~90 % of
//!             │   count 1–3 ──▶ emit count syms            │ positions
//!             │   count = 0 ──▶ DecodeLut (≤ 16-bit code)  │ ≪ 1 %
//!             └────────────────────────────────────────────┘
//!   tail (< 4 slots left) ──▶ single-symbol loop
//! ```
//!
//! ## Refill invariants ([`BitCursor`])
//!
//! The cursor keeps live bits MSB-aligned in a u64 register across
//! refills instead of re-reading from the bit position each outer
//! iteration (the pre-rework sweep discarded up to 15 live bits per
//! refill). Invariants:
//!
//! * `w`'s top `avail` bits are the next unconsumed stream bits;
//! * `refill` ORs in the next unaligned u64 below them and advances the
//!   byte pointer by the number of *whole* bytes absorbed, leaving
//!   `avail ∈ [56, 63]` — fractional-byte bits are deliberately re-read
//!   (identically) by the next refill, which keeps the advance exact
//!   without any flag or branch on the bit phase;
//! * `consume(k)` requires `k ≤ avail` (every tier consumes ≤ 16 bits
//!   against ≥ 56 available, so one refill per lookup suffices).
//!
//! Loads past the buffer end are zero-filled; the encoder pads the
//! encoded stream with 8 slack bytes so the hot branch stays perfectly
//! predictable, and the packed nibble plane (no slack) only hits the
//! zero-fill branch in its final refills.
//!
//! The public entry point [`decode_into`] fans blocks out over a thread
//! pool; blocks write disjoint output slices (`outpos[b] .. outpos[b+1]`).
//! Serving paths that decode the same tensor repeatedly should build the
//! LUT tiers once via [`DecodeTables`] and call [`decode_into_cached`]
//! (the JIT decompressor caches tables per code book).

use super::simd;
use super::{Ecf8Blob, Fp8Format};
use crate::huffman::bitstream::BitReader;
use crate::huffman::lut::{DecodeLut, MultiLut, PairLut, MULTI_MAX_SYMS};
use crate::util::threadpool::ThreadPool;
use std::collections::HashMap;
use std::sync::Arc;

/// Which decode implementation to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodePath {
    /// Multi-symbol LUT + branchless carry-forward refill (default).
    #[default]
    Fast,
    /// Pair-LUT sweep with reload-per-refill (previous default; ablation).
    FastPair,
    /// Fast sweep without any multi-symbol LUT (ablation).
    FastSingle,
    /// Faithful Algorithm-1 per-thread two-phase simulation.
    Alg1,
}

/// Prebuilt decode tiers for one code book. Building costs ~80 k LUT
/// probes (dominated by the 16 k-entry [`MultiLut`]); amortize it across
/// decodes of the same tensor by reusing one `DecodeTables`.
#[derive(Debug, Clone)]
pub struct DecodeTables {
    pub(crate) lut: DecodeLut,
    pub(crate) multi: Option<MultiLut>,
    pub(crate) pair: Option<PairLut>,
}

impl DecodeTables {
    /// Build the tiers the default ([`DecodePath::Fast`]) engine uses —
    /// what the caching serving path wants. The pair tier is ablation-only
    /// and deliberately left unbuilt here (it would be 16 KiB of dead
    /// table per cached code book).
    pub fn build(blob: &Ecf8Blob) -> Self {
        let lut = blob.lut();
        let multi = MultiLut::build(&lut);
        Self {
            lut,
            multi: Some(multi),
            pair: None,
        }
    }

    /// Build only the tiers `path` dispatches to.
    fn for_path(blob: &Ecf8Blob, path: DecodePath) -> Self {
        let lut = blob.lut();
        let multi = matches!(path, DecodePath::Fast).then(|| MultiLut::build(&lut));
        let pair = matches!(path, DecodePath::FastPair).then(|| PairLut::build(&lut));
        Self { lut, multi, pair }
    }
}

/// Shared cache of [`DecodeTables`] keyed by code book (the stored
/// canonical lengths fully determine the book). Layers routinely share
/// books, so the serving paths — the JIT decompressor and the
/// coordinator's decode-ahead stage — build each table set once and clone
/// `Arc`s from here.
#[derive(Debug, Default)]
pub struct DecodeTableCache {
    map: HashMap<Vec<u8>, Arc<DecodeTables>>,
}

impl DecodeTableCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Tables for `blob`'s code book, building them on first use.
    pub fn get_or_build(&mut self, blob: &Ecf8Blob) -> Arc<DecodeTables> {
        self.map
            .entry(blob.code_lengths.clone())
            .or_insert_with(|| Arc::new(DecodeTables::build(blob)))
            .clone()
    }

    /// Number of distinct code books cached.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Decode the whole blob into `out` (must be exactly `n_elem` bytes).
/// `pool`: optional thread pool for block parallelism; `None` = serial.
pub fn decode_into(blob: &Ecf8Blob, out: &mut [u8], pool: Option<&ThreadPool>) {
    decode_into_path(blob, out, pool, DecodePath::Fast)
}

/// Decode with an explicit implementation choice (benches/tests).
pub fn decode_into_path(
    blob: &Ecf8Blob,
    out: &mut [u8],
    pool: Option<&ThreadPool>,
    path: DecodePath,
) {
    let tables = DecodeTables::for_path(blob, path);
    decode_blocks(blob, out, pool, path, &tables)
}

/// Decode on the default path with prebuilt [`DecodeTables`] — the hot
/// serving entry point (no per-call LUT construction).
pub fn decode_into_cached(
    blob: &Ecf8Blob,
    out: &mut [u8],
    pool: Option<&ThreadPool>,
    tables: &DecodeTables,
) {
    decode_blocks(blob, out, pool, DecodePath::Fast, tables)
}

fn decode_blocks(
    blob: &Ecf8Blob,
    out: &mut [u8],
    pool: Option<&ThreadPool>,
    path: DecodePath,
    tables: &DecodeTables,
) {
    assert_eq!(out.len(), blob.n_elem, "output buffer size mismatch");
    let n_blocks = blob.n_blocks();

    // Blocks own disjoint output ranges outpos[b]..outpos[b+1]; hand each
    // worker the output base address and rely on that disjointness (same
    // contract as the CUDA kernel's non-overlapping shared-memory slices).
    let out_addr = out.as_mut_ptr() as usize;
    let out_len = out.len();

    let run_block = |b: usize| {
        let lo = blob.outpos[b] as usize;
        let hi = blob.outpos[b + 1] as usize;
        debug_assert!(lo <= hi && hi <= out_len);
        // SAFETY: [lo, hi) ranges are disjoint across blocks and in-bounds.
        let slice =
            unsafe { std::slice::from_raw_parts_mut((out_addr as *mut u8).add(lo), hi - lo) };
        match path {
            DecodePath::Fast => decode_block_fast_multi(
                blob,
                &tables.lut,
                tables.multi.as_ref().expect("multi tier built"),
                b,
                slice,
            ),
            DecodePath::FastPair => decode_block_fast_pair(
                blob,
                &tables.lut,
                tables.pair.as_ref().expect("pair tier built"),
                b,
                slice,
            ),
            DecodePath::FastSingle => decode_block_fast(blob, &tables.lut, b, slice),
            DecodePath::Alg1 => decode_block_alg1(blob, &tables.lut, b, slice),
        }
    };

    match pool {
        Some(pool) => pool.scope_chunks(n_blocks, pool.size() * 4, |_, s, e| {
            for b in s..e {
                run_block(b);
            }
        }),
        None => {
            for b in 0..n_blocks {
                run_block(b);
            }
        }
    }
}

/// Extract thread `t_g`'s 4-bit gap (Algorithm 1 line 5).
#[inline(always)]
fn gap_of(gaps: &[u8], t_g: usize) -> u32 {
    ((gaps[t_g / 2] >> (4 - (t_g % 2) * 4)) & 0x0F) as u32
}

/// Extract the rest nibble of output element `o` (Algorithm 1 line 23).
#[inline(always)]
fn rest_of(packed: &[u8], o: usize) -> u8 {
    (packed[o / 2] >> (4 - (o % 2) * 4)) & 0x0F
}

// ---------------------------------------------------------------------------
// Branchless carry-forward bit reader
// ---------------------------------------------------------------------------

/// MSB-first bit cursor whose live bits survive refills in-register (see
/// the module docs for the invariants). Works over any byte slice; loads
/// past the end read as zero, so a slack-padded buffer (the encoded
/// stream) never leaves the predictable fast-load branch while an
/// unpadded one (the packed nibble plane) degrades gracefully at its
/// tail.
struct BitCursor<'a> {
    buf: &'a [u8],
    /// next byte to absorb
    next: usize,
    /// MSB-aligned live bits; everything below the top `avail` bits that
    /// has been ORed in is genuine stream data awaiting re-absorption
    w: u64,
    /// guaranteed-valid bit count at the top of `w` (≤ 63)
    avail: u32,
}

impl<'a> BitCursor<'a> {
    #[inline(always)]
    fn new(buf: &'a [u8], bitpos: usize) -> Self {
        let mut c = Self {
            buf,
            next: bitpos >> 3,
            w: 0,
            avail: 0,
        };
        c.refill();
        c.consume((bitpos & 7) as u32);
        c
    }

    /// Top up to `avail ∈ [56, 63]` with one unaligned big-endian u64
    /// load (Giesen's "variant 4" refill: advance by whole bytes only,
    /// `avail |= 56`).
    #[inline(always)]
    fn refill(&mut self) {
        let chunk = if self.next + 8 <= self.buf.len() {
            u64::from_be_bytes(self.buf[self.next..self.next + 8].try_into().unwrap())
        } else {
            // zero-filled tail load (packed nibble plane has no slack)
            let mut tmp = [0u8; 8];
            if self.next < self.buf.len() {
                let rem = self.buf.len() - self.next;
                tmp[..rem].copy_from_slice(&self.buf[self.next..]);
            }
            u64::from_be_bytes(tmp)
        };
        debug_assert!(self.avail < 64);
        self.w |= chunk >> self.avail;
        self.next += ((63 - self.avail) >> 3) as usize;
        self.avail |= 56;
    }

    /// The 64-bit MSB-aligned window (top `avail` bits guaranteed live).
    #[inline(always)]
    fn peek(&self) -> u64 {
        self.w
    }

    #[inline(always)]
    fn consume(&mut self, bits: u32) {
        debug_assert!(bits <= self.avail, "consume {bits} of {}", self.avail);
        self.w <<= bits;
        self.avail -= bits;
    }
}

// ---------------------------------------------------------------------------
// Multi-symbol fast path
// ---------------------------------------------------------------------------

/// Decode block `b` with the multi-symbol engine: one [`BitCursor`] over
/// the Huffman stream, one over the packed nibble plane, [`MultiLut`]
/// dispatch emitting up to 4 symbols per lookup (see the module-doc tier
/// diagram), and SIMD/SWAR nibble assembly ([`simd`]) retiring up to 16
/// output bytes per store.
///
/// The 16-wide gather rides the [`BitCursor`] refill invariant: one
/// refill leaves ≥ 56 live bits and a full-count [`MultiLut`] entry
/// consumes ≤ 14, so up to four lookups resolve off a single refill —
/// before the g-th gathered lookup at least `56 − 14·g ≥ 14` valid bits
/// remain at the top of the window, exactly the table's index width.
pub fn decode_block_fast_multi(
    blob: &Ecf8Blob,
    lut: &DecodeLut,
    multi: &MultiLut,
    b: usize,
    out_block: &mut [u8],
) {
    let block_bytes = blob.params.block_bytes();
    let start_byte = b * block_bytes;
    let t0 = b * blob.params.threads_per_block;
    let gap = gap_of(&blob.gaps, t0) as usize;
    let o_base = blob.outpos[b] as usize;
    let n = out_block.len();
    if n == 0 {
        return;
    }
    let enc = &blob.encoded[..];
    let packed = &blob.packed[..];
    let spec = simd::FormatSpec::of(blob.format);

    let mut bits = BitCursor::new(enc, start_byte * 8 + gap);
    // nibble i lives at bit 4·i of the packed plane (high nibble first)
    let mut nibs = BitCursor::new(packed, o_base * 4);
    let mut o = 0usize;

    macro_rules! sweep {
        ($assemble:expr) => {{
            while o + MULTI_MAX_SYMS <= n {
                bits.refill();
                let e = multi.lookup(bits.peek());
                let count = MultiLut::count(e);
                if count == MULTI_MAX_SYMS {
                    bits.consume(MultiLut::consumed(e));
                    nibs.refill();
                    let r0 = (nibs.peek() >> 48) as u16;
                    nibs.consume(16);
                    if o + 16 <= n {
                        // gather up to 3 more full-count windows off the
                        // same refill and retire 16 bytes in one store
                        let mut sym_words = [MultiLut::sym_bytes(e), 0, 0, 0];
                        let mut rests = [r0, 0, 0, 0];
                        let mut g = 1usize;
                        while g < 4 {
                            let e2 = multi.lookup(bits.peek());
                            if MultiLut::count(e2) != MULTI_MAX_SYMS {
                                break;
                            }
                            bits.consume(MultiLut::consumed(e2));
                            nibs.refill();
                            rests[g] = (nibs.peek() >> 48) as u16;
                            nibs.consume(16);
                            sym_words[g] = MultiLut::sym_bytes(e2);
                            g += 1;
                        }
                        if g == 4 {
                            let dst: &mut [u8; 16] =
                                (&mut out_block[o..o + 16]).try_into().unwrap();
                            simd::assemble16(spec, &sym_words, &rests, dst);
                            o += 16;
                        } else {
                            // partial gather (long-code window ahead):
                            // flush what we have 4 bytes at a time
                            for i in 0..g {
                                out_block[o..o + 4].copy_from_slice(&simd::assemble4(
                                    spec,
                                    sym_words[i],
                                    rests[i],
                                ));
                                o += 4;
                            }
                        }
                    } else {
                        out_block[o..o + 4].copy_from_slice(&simd::assemble4(
                            spec,
                            MultiLut::sym_bytes(e),
                            r0,
                        ));
                        o += 4;
                    }
                } else if count > 0 {
                    // long-code window: 1–3 symbols still resolved in one
                    // lookup
                    bits.consume(MultiLut::consumed(e));
                    nibs.refill();
                    for k in 0..count {
                        let rest = (nibs.peek() >> 60) as u8;
                        nibs.consume(4);
                        out_block[o + k] = $assemble(MultiLut::sym(e, k), rest);
                    }
                    o += count;
                } else {
                    // leading code wider than the multi window (> 14 bits)
                    let (x, len) = lut.decode((bits.peek() >> 48) as u16);
                    bits.consume(len);
                    nibs.refill();
                    let rest = (nibs.peek() >> 60) as u8;
                    nibs.consume(4);
                    out_block[o] = $assemble(x as u8, rest);
                    o += 1;
                }
            }
            // tail: fewer than 4 slots left — single-symbol steps so a
            // greedy multi entry can never overrun the block's output
            while o < n {
                bits.refill();
                let (x, len) = lut.decode((bits.peek() >> 48) as u16);
                bits.consume(len);
                nibs.refill();
                let rest = (nibs.peek() >> 60) as u8;
                nibs.consume(4);
                out_block[o] = $assemble(x as u8, rest);
                o += 1;
            }
        }};
    }

    match blob.format {
        Fp8Format::E4M3 => {
            sweep!(|x: u8, rest: u8| ((rest & 0x08) << 4) | (x << 3) | (rest & 0x07))
        }
        Fp8Format::E5M2 => {
            sweep!(|x: u8, rest: u8| ((rest & 0x04) << 5) | (x << 2) | (rest & 0x03))
        }
    }
}

// ---------------------------------------------------------------------------
// Faithful Algorithm-1 path
// ---------------------------------------------------------------------------

/// Decode block `b` exactly as Algorithm 1: two phases over T simulated
/// threads with an exclusive prefix sum between them. `out_block` is the
/// block's disjoint output slice (`outpos[b]..outpos[b+1]`).
pub fn decode_block_alg1(blob: &Ecf8Blob, lut: &DecodeLut, b: usize, out_block: &mut [u8]) {
    let t_per_block = blob.params.threads_per_block;
    let b_bytes = blob.params.bytes_per_thread;
    let window_bits = (b_bytes * 8) as u32;
    let o_base = blob.outpos[b] as usize;
    let o_block_end = blob.outpos[b + 1] as usize;
    let n_elem = blob.n_elem;
    if o_base == o_block_end {
        // nothing to produce (empty tensor); the padding windows would
        // only count garbage
        return;
    }

    // ---- Phase 1: per-thread symbol counting (lines 6–15) ----
    let mut counts = vec![0u32; t_per_block];
    for t in 0..t_per_block {
        let t_g = b * t_per_block + t;
        let byte_off = t_g * b_bytes;
        let gap = gap_of(&blob.gaps, t_g);
        // bits available to *start* a codeword in this window
        let mut consumed = gap;
        let mut lr = WindowReader::new(&blob.encoded, byte_off, b_bytes, gap);
        let mut c = 0u32;
        while consumed < window_bits {
            let (_, len) = lut.decode(lr.peek16());
            if len == 0 {
                // unreachable with a complete code; reachable only in
                // zero-padding under a degenerate (single-symbol) book
                break;
            }
            lr.skip(len);
            consumed += len;
            c += 1;
        }
        counts[t] = c;
    }

    // ---- Block-level exclusive prefix sum (lines 16–19) ----
    // accum[t] = outpos[b] + sum counts[0..t]; accum[T] forced to
    // outpos[b+1] (the metadata bound wins over padding overcount).
    let mut accum = vec![0usize; t_per_block + 1];
    accum[0] = o_base;
    for t in 0..t_per_block {
        accum[t + 1] = accum[t] + counts[t] as usize;
    }
    accum[t_per_block] = o_block_end;

    // ---- Phase 2: decode and assemble FP8 (lines 20–31) ----
    let format = blob.format;
    for t in 0..t_per_block {
        let t_g = b * t_per_block + t;
        let byte_off = t_g * b_bytes;
        let gap = gap_of(&blob.gaps, t_g);
        let o_start = accum[t];
        let o_end = (accum[t] + counts[t] as usize)
            .min(n_elem)
            .min(o_block_end);
        let mut lr = WindowReader::new(&blob.encoded, byte_off, b_bytes, gap);
        let mut o = o_start;
        while o < o_end {
            let (x, len) = lut.decode(lr.peek16());
            lr.skip(len);
            let rest = rest_of(&blob.packed, o);
            out_block[o - o_base] = format.assemble(x as u8, rest);
            o += 1;
        }
    }
}

/// The 80-bit (head+tail) register window of Algorithm 1, expressed as a
/// safe reader: `peek16`/`skip` over the thread's B+2 loaded bytes. The
/// arithmetic mirrors lines 4–12: a u64 head `L`, u16 tail `S`, stitch at
/// 16 remaining bits.
struct WindowReader {
    l: u64,
    s: u16,
    /// bits consumed so far (including the initial gap)
    f: u32,
    stitched: bool,
}

impl WindowReader {
    #[inline(always)]
    fn new(encoded: &[u8], byte_off: usize, b_bytes: usize, gap: u32) -> Self {
        // Supported geometries: B = 8 (the faithful 64-bit head + 16-bit
        // tail) or B <= 6 (the 8-byte head already covers B+2 bytes, so
        // the worst-case read 8B-1+16 <= 63 bits never leaves the head).
        debug_assert!(
            b_bytes == 8 || b_bytes <= 6,
            "bytes_per_thread must be 8 or <= 6 (got {b_bytes})"
        );
        let mut head = [0u8; 8];
        head[..8].copy_from_slice(&encoded[byte_off..byte_off + 8]);
        let l = u64::from_be_bytes(head);
        let s = u16::from_be_bytes([encoded[byte_off + b_bytes], encoded[byte_off + b_bytes + 1]]);
        let mut r = Self {
            l,
            s,
            f: 0,
            // For B < 8 the tail bytes are already inside the head load.
            stitched: b_bytes < 8,
        };
        r.skip_raw(gap);
        r
    }

    #[inline(always)]
    fn peek16(&self) -> u16 {
        (self.l >> 48) as u16
    }

    #[inline(always)]
    fn skip_raw(&mut self, bits: u32) {
        self.l <<= bits;
        self.f += bits;
        if !self.stitched && self.f > 48 {
            // fewer than 16 valid head bits remain: stitch the tail in at
            // its correct position (Alg. 1 lines 12 / 28:
            // L |= S << (f - 16) — in our orientation the tail lands
            // `64 - (80 - f)` bits from the top).
            self.l |= (self.s as u64) << self.f.saturating_sub(16).min(48);
            self.stitched = true;
        }
    }

    #[inline(always)]
    fn skip(&mut self, bits: u32) {
        self.skip_raw(bits);
    }
}

// ---------------------------------------------------------------------------
// CPU pair / single sweeps (ablation tiers)
// ---------------------------------------------------------------------------

/// Decode block `b` in one sequential sweep with unaligned u64 refills
/// and pair-LUT dispatch (two symbols per lookup where the pair table
/// covers — see [`crate::huffman::lut::PairLut`]). Superseded by
/// [`decode_block_fast_multi`]; kept as the ablation tier that isolates
/// the multi-LUT + carry-forward-refill gains.
pub fn decode_block_fast_pair(
    blob: &Ecf8Blob,
    lut: &DecodeLut,
    pair: &PairLut,
    b: usize,
    out_block: &mut [u8],
) {
    let block_bytes = blob.params.block_bytes();
    let start_byte = b * block_bytes;
    let t0 = b * blob.params.threads_per_block;
    let gap = gap_of(&blob.gaps, t0) as u64;
    let o_base = blob.outpos[b] as usize;
    let o_end = blob.outpos[b + 1] as usize;
    let n = o_end - o_base;
    if n == 0 {
        return;
    }
    let enc = &blob.encoded;
    let packed = &blob.packed;
    let format = blob.format;
    let mut bitpos = (start_byte as u64) * 8 + gap;
    let mut o = 0usize;

    macro_rules! sweep {
        ($assemble:expr) => {{
            while o < n {
                let byte = (bitpos >> 3) as usize;
                let sh = (bitpos & 7) as u32;
                let w0 = u64::from_be_bytes(enc[byte..byte + 8].try_into().unwrap());
                let mut w = w0 << sh;
                let mut avail = 64 - sh;
                loop {
                    // pair fast path: needs 2 output slots and >= 12 bits
                    if o + 2 <= n && avail >= 12 {
                        if let Some((x1, x2, len)) = pair.decode_pair(w) {
                            w <<= len;
                            avail -= len;
                            bitpos += len as u64;
                            let oo = o_base + o;
                            // both rest nibbles in one load when aligned
                            let (r1, r2) = if oo & 1 == 0 {
                                let pb = packed[oo >> 1];
                                (pb >> 4, pb & 0x0F)
                            } else {
                                (packed[oo >> 1] & 0x0F, packed[(oo >> 1) + 1] >> 4)
                            };
                            out_block[o] = $assemble(x1, r1);
                            out_block[o + 1] = $assemble(x2, r2);
                            o += 2;
                            if o == n || avail < 16 {
                                break;
                            }
                            continue;
                        }
                    }
                    if avail < 16 {
                        break;
                    }
                    let (x, len) = lut.decode((w >> 48) as u16);
                    w <<= len;
                    avail -= len;
                    bitpos += len as u64;
                    let oo = o_base + o;
                    let rest = (packed[oo / 2] >> (4 - (oo % 2) * 4)) & 0x0F;
                    out_block[o] = $assemble(x as u8, rest);
                    o += 1;
                    if o == n || avail < 16 {
                        break;
                    }
                }
            }
        }};
    }

    match format {
        Fp8Format::E4M3 => {
            sweep!(|x: u8, rest: u8| ((rest & 0x08) << 4) | (x << 3) | (rest & 0x07))
        }
        Fp8Format::E5M2 => {
            sweep!(|x: u8, rest: u8| ((rest & 0x04) << 5) | (x << 2) | (rest & 0x03))
        }
    }
}

/// Decode block `b` in one sequential sweep with unaligned u64 refills.
pub fn decode_block_fast(blob: &Ecf8Blob, lut: &DecodeLut, b: usize, out_block: &mut [u8]) {
    let block_bytes = blob.params.block_bytes();
    let start_byte = b * block_bytes;
    let t0 = b * blob.params.threads_per_block;
    let gap = gap_of(&blob.gaps, t0) as u64;
    let o_base = blob.outpos[b] as usize;
    let o_end = blob.outpos[b + 1] as usize;
    let n = o_end - o_base;
    if n == 0 {
        return;
    }

    let enc = &blob.encoded;
    let packed = &blob.packed;
    let format = blob.format;
    let mut bitpos = (start_byte as u64) * 8 + gap;
    let mut o = 0usize;

    // Assemble format constants outside the loop; E4M3 dominates, keep the
    // match out of the hot loop by monomorphising per format.
    macro_rules! sweep {
        ($assemble:expr) => {{
            while o < n {
                // refill: 64-bit window starting at bitpos (encoded has
                // >= 8 bytes of zero slack past every block)
                let byte = (bitpos >> 3) as usize;
                let sh = (bitpos & 7) as u32;
                let w0 = u64::from_be_bytes(enc[byte..byte + 8].try_into().unwrap());
                let mut w = w0 << sh;
                let mut avail = 64 - sh;
                loop {
                    let (x, len) = lut.decode((w >> 48) as u16);
                    w <<= len;
                    avail -= len;
                    bitpos += len as u64;
                    let oo = o_base + o;
                    let rest = (packed[oo / 2] >> (4 - (oo % 2) * 4)) & 0x0F;
                    out_block[o] = $assemble(x as u8, rest);
                    o += 1;
                    if o == n {
                        break;
                    }
                    if avail < 16 {
                        break;
                    }
                }
            }
        }};
    }

    match format {
        Fp8Format::E4M3 => {
            sweep!(|x: u8, rest: u8| ((rest & 0x08) << 4) | (x << 3) | (rest & 0x07))
        }
        Fp8Format::E5M2 => {
            sweep!(|x: u8, rest: u8| ((rest & 0x04) << 5) | (x << 2) | (rest & 0x03))
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar reference
// ---------------------------------------------------------------------------

/// Ground-truth decoder: sequential prefix-match over the whole stream.
pub fn decode_scalar_reference(blob: &Ecf8Blob) -> Vec<u8> {
    let code = blob.code();
    let mut out = vec![0u8; blob.n_elem];
    let mut reader = BitReader::new(&blob.encoded);
    for (o, slot) in out.iter_mut().enumerate() {
        let window = reader.peek16();
        let (sym, len) = code
            .decode_window(window)
            .expect("valid stream decodes a symbol");
        reader.skip(len);
        let rest = rest_of(&blob.packed, o);
        *slot = blob.format.assemble(sym as u8, rest);
    }
    out
}

/// Every decode path, for exhaustive cross-checking in tests/benches.
pub const ALL_PATHS: [DecodePath; 4] = [
    DecodePath::Fast,
    DecodePath::FastPair,
    DecodePath::FastSingle,
    DecodePath::Alg1,
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::encode::{encode, encode_with_code};
    use crate::codec::{Ecf8Params, Fp8Format};
    use crate::huffman::canonical::CanonicalCode;
    use crate::util::prng::Xoshiro256;
    use crate::util::quickprop::{property, Gen};

    fn weight_bytes(n: usize, seed: u64, scale: f64) -> Vec<u8> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                let x = (crate::util::sampling::normal(&mut rng) * scale) as f32;
                crate::fp8::F8E4M3::from_f32(x).to_bits()
            })
            .collect()
    }

    fn roundtrip(data: &[u8], fmt: Fp8Format, params: Ecf8Params, path: DecodePath) {
        let blob = encode(data, fmt, params);
        let mut out = vec![0u8; data.len()];
        decode_into_path(&blob, &mut out, None, path);
        assert_eq!(out, data, "path {path:?} params {params:?}");
    }

    #[test]
    fn fast_path_bit_exact_small() {
        for n in [0usize, 1, 2, 3, 7, 255, 256, 1000] {
            let data = weight_bytes(n, n as u64 + 1, 0.05);
            for path in [DecodePath::Fast, DecodePath::FastPair] {
                roundtrip(&data, Fp8Format::E4M3, Ecf8Params::default(), path);
            }
        }
    }

    #[test]
    fn alg1_path_bit_exact_small() {
        for n in [0usize, 1, 5, 100, 2048, 10_000] {
            let data = weight_bytes(n, n as u64 + 10, 0.05);
            roundtrip(&data, Fp8Format::E4M3, Ecf8Params::default(), DecodePath::Alg1);
        }
    }

    #[test]
    fn all_paths_bit_exact_multi_block() {
        // > 1 block with default geometry requires > 2048 encoded bytes
        let data = weight_bytes(200_000, 42, 0.02);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        assert!(blob.n_blocks() > 1, "want multi-block, got {}", blob.n_blocks());
        for path in ALL_PATHS {
            let mut out = vec![0u8; data.len()];
            decode_into_path(&blob, &mut out, None, path);
            assert_eq!(out, data, "{path:?}");
        }
    }

    #[test]
    fn parallel_decode_matches_serial() {
        let pool = ThreadPool::new(4);
        let data = weight_bytes(500_000, 7, 0.05);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        let mut a = vec![0u8; data.len()];
        let mut b = vec![0u8; data.len()];
        decode_into(&blob, &mut a, Some(&pool));
        decode_into(&blob, &mut b, None);
        assert_eq!(a, b);
        assert_eq!(a, data);
    }

    #[test]
    fn scalar_reference_agrees() {
        let data = weight_bytes(30_000, 8, 0.1);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        assert_eq!(decode_scalar_reference(&blob), data);
    }

    #[test]
    fn cached_tables_decode_matches() {
        let data = weight_bytes(100_000, 12, 0.05);
        let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
        let tables = DecodeTables::build(&blob);
        let mut out = vec![0u8; data.len()];
        decode_into_cached(&blob, &mut out, None, &tables);
        assert_eq!(out, data);
        // reuse the same tables (the serving pattern)
        out.fill(0);
        decode_into_cached(&blob, &mut out, None, &tables);
        assert_eq!(out, data);
    }

    #[test]
    fn e5m2_roundtrip() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let data: Vec<u8> = (0..50_000)
            .map(|_| {
                let x = (crate::util::sampling::normal(&mut rng) * 0.05) as f32;
                crate::fp8::F8E5M2::from_f32(x).to_bits()
            })
            .collect();
        for path in ALL_PATHS {
            roundtrip(&data, Fp8Format::E5M2, Ecf8Params::default(), path);
        }
    }

    #[test]
    fn nonstandard_geometry_roundtrips() {
        // smaller threads-per-block and bytes-per-thread stress the gap /
        // outpos bookkeeping
        for (bt, tpb) in [(8usize, 32usize), (8, 1), (8, 1024), (4, 64), (6, 16)] {
            let params = Ecf8Params {
                bytes_per_thread: bt,
                threads_per_block: tpb,
            };
            let data = weight_bytes(60_000, (bt * tpb) as u64, 0.05);
            for path in ALL_PATHS {
                roundtrip(&data, Fp8Format::E4M3, params, path);
            }
        }
    }

    #[test]
    fn adversarial_uniform_bytes_roundtrip() {
        let mut rng = Xoshiro256::seed_from_u64(10);
        let data: Vec<u8> = (0..123_457).map(|_| (rng.next_u64() >> 56) as u8).collect();
        for path in ALL_PATHS {
            roundtrip(&data, Fp8Format::E4M3, Ecf8Params::default(), path);
        }
    }

    #[test]
    fn all_same_exponent_roundtrip() {
        // degenerate single-symbol alphabet: code length forced to 1
        let data = vec![0x38u8; 10_000]; // 1.0 repeated
        for path in ALL_PATHS {
            roundtrip(&data, Fp8Format::E4M3, Ecf8Params::default(), path);
        }
    }

    /// A deliberately pathological code book: Fibonacci-ish frequencies
    /// drive the rarest exponent symbols to the 16-bit MAX_CODE_LEN
    /// ceiling, exercising the multi-LUT fallback tier and the two-level
    /// single LUT on real streams.
    fn max_depth_code() -> CanonicalCode {
        let mut freqs = vec![0u64; 16];
        let (mut a, mut b) = (1u64, 1u64);
        for f in freqs.iter_mut() {
            *f = a;
            let c = a + b;
            a = b;
            b = c;
        }
        let code = CanonicalCode::from_frequencies(&freqs);
        assert!(code.max_len() >= 15, "want deep codes, got {}", code.max_len());
        code
    }

    #[test]
    fn max_length_codes_hit_fallback_tier_and_stay_exact() {
        let code = max_depth_code();
        // Bias the data towards the frequency-poorest symbols (low fib
        // indices ⇒ longest codes) so 15/16-bit codewords are dense in
        // the stream, not just representable.
        let mut rng = Xoshiro256::seed_from_u64(33);
        let data: Vec<u8> = (0..80_000)
            .map(|_| {
                let sym = if rng.next_u64() & 3 == 0 {
                    (rng.next_u64() % 16) as u8 // occasional short codes
                } else {
                    (rng.next_u64() % 4) as u8 // mostly 13–16-bit codes
                };
                let rest = (rng.next_u64() & 0x0F) as u8;
                Fp8Format::E4M3.assemble(sym, rest)
            })
            .collect();
        let blob = encode_with_code(&data, Fp8Format::E4M3, Ecf8Params::default(), &code);
        let reference = decode_scalar_reference(&blob);
        assert_eq!(reference, data);
        for path in ALL_PATHS {
            let mut out = vec![0u8; data.len()];
            decode_into_path(&blob, &mut out, None, path);
            assert_eq!(out, data, "{path:?}");
        }
    }

    #[test]
    fn property_all_paths_match_scalar_reference() {
        property(
            "every decode path == scalar reference on adversarial tensors",
            40,
            |g: &mut Gen| {
                let n = g.usize_in(0..=8192);
                // mix of uniform bytes and weight-like bytes
                let data: Vec<u8> = if g.bool() {
                    (0..n).map(|_| g.u8()).collect()
                } else {
                    (0..n)
                        .map(|_| {
                            let x = (g.f32() - 0.5) * 0.1;
                            crate::fp8::F8E4M3::from_f32(x).to_bits()
                        })
                        .collect()
                };
                let params = *g.choose(&[
                    Ecf8Params::default(),
                    Ecf8Params {
                        bytes_per_thread: 8,
                        threads_per_block: 32,
                    },
                    Ecf8Params {
                        bytes_per_thread: 4,
                        threads_per_block: 128,
                    },
                ]);
                let fmt = *g.choose(&[Fp8Format::E4M3, Fp8Format::E5M2]);
                let blob = encode(&data, fmt, params);
                let reference = decode_scalar_reference(&blob);
                assert_eq!(reference, data);
                for path in ALL_PATHS {
                    let mut out = vec![0u8; n];
                    decode_into_path(&blob, &mut out, None, path);
                    assert_eq!(out, reference, "{path:?}");
                }
            },
        );
    }

    #[test]
    fn property_roundtrip_random_tensors() {
        property("ecf8 roundtrip on arbitrary byte tensors", 60, |g: &mut Gen| {
            let n = g.usize_in(0..=8192);
            let data: Vec<u8> = (0..n).map(|_| g.u8()).collect();
            let params = *g.choose(&[
                Ecf8Params::default(),
                Ecf8Params {
                    bytes_per_thread: 8,
                    threads_per_block: 32,
                },
                Ecf8Params {
                    bytes_per_thread: 4,
                    threads_per_block: 128,
                },
            ]);
            let fmt = *g.choose(&[Fp8Format::E4M3, Fp8Format::E5M2]);
            let blob = encode(&data, fmt, params);
            let mut out = vec![0u8; n];
            let path = *g.choose(&ALL_PATHS);
            decode_into_path(&blob, &mut out, None, path);
            assert_eq!(out, data);
        });
    }

    #[test]
    fn property_weightlike_heavy_tail_roundtrip() {
        property("ecf8 roundtrip on weight-like tensors", 40, |g: &mut Gen| {
            let ws = g.vec_weights(1..=4096);
            let data: Vec<u8> = ws
                .iter()
                .map(|&w| crate::fp8::F8E4M3::from_f32(w).to_bits())
                .collect();
            let blob = encode(&data, Fp8Format::E4M3, Ecf8Params::default());
            let mut out = vec![0u8; data.len()];
            decode_into(&blob, &mut out, None);
            assert_eq!(out, data);
        });
    }
}
