//! SIMD/SWAR nibble assembly — the vectorized back half of the
//! multi-symbol decode fast path.
//!
//! The multi-symbol engine ([`crate::codec::decode`]) resolves exponent
//! symbols four at a time out of the [`crate::huffman::lut::MultiLut`];
//! what remains per output byte is pure data movement: merge each 5-bit
//! exponent symbol with its 4-bit sign/mantissa ("rest") nibble back into
//! an FP8 byte. Done scalar, that movement is the LUT-dispatch bound the
//! ROADMAP calls out. This module does it 4 or 16 bytes at a time:
//!
//! * [`assemble4`] — one [`MultiLut`] entry (4 symbols in byte lanes) +
//!   16 bits of rest nibbles → 4 FP8 bytes, via portable u32 SWAR;
//! * [`assemble16`] — four consecutive full-count entries + 64 bits of
//!   rest nibbles → 16 FP8 bytes in one store.
//!
//! ## Tier matrix (`#[cfg]`)
//!
//! | tier     | selected when                                     |
//! |----------|---------------------------------------------------|
//! | `sse2`   | `x86_64` (SSE2 is baseline) and not `force-swar`  |
//! | `neon`   | `aarch64` (NEON is baseline) and not `force-swar` |
//! | `swar64` | any other arch, or the `force-swar` cargo feature |
//!
//! The portable SWAR kernels are always compiled (they back `assemble4`
//! everywhere and `assemble16` on the `swar64` tier) and every tier is
//! pinned to them by tests, so CI exercising `--features force-swar` on
//! x86_64 covers the exact code path a no-SIMD target would run.
//!
//! ## Bit-layout contract
//!
//! Per FP8 byte (matching [`Fp8Format::assemble`]):
//!
//! * E4M3: `out = (rest & 8) << 4 | sym << 3 | (rest & 7)`
//! * E5M2: `out = (rest & 4) << 5 | sym << 2 | (rest & 3)`
//!
//! Symbols arrive in byte lanes already (`MultiLut::sym_bytes`), capped
//! below 32 by the table builder, so the lane shift (`<< 3` / `<< 2`)
//! cannot carry across byte boundaries. Rest nibbles arrive as the next
//! 16 (or 64) MSB-first bits of the packed nibble plane: nibble `k` of
//! the operand is the rest of output byte `k`.

use super::Fp8Format;

/// Human-readable name of the compiled assembly tier (benches/logs).
#[cfg(all(not(feature = "force-swar"), target_arch = "x86_64"))]
pub const TIER: &str = "sse2";
#[cfg(all(not(feature = "force-swar"), target_arch = "aarch64"))]
pub const TIER: &str = "neon";
#[cfg(any(
    feature = "force-swar",
    not(any(target_arch = "x86_64", target_arch = "aarch64"))
))]
pub const TIER: &str = "swar64";

/// Per-format SWAR constants: (sym lane shift, sign mask, sign shift,
/// low-bits mask), each replicated across the four byte lanes where the
/// kernels need it.
#[derive(Debug, Clone, Copy)]
pub struct FormatSpec {
    pub sym_shift: u32,
    pub sign_mask: u8,
    pub sign_shift: u32,
    pub low_mask: u8,
}

impl FormatSpec {
    #[inline(always)]
    pub const fn of(format: Fp8Format) -> Self {
        match format {
            Fp8Format::E4M3 => FormatSpec {
                sym_shift: 3,
                sign_mask: 0x08,
                sign_shift: 4,
                low_mask: 0x07,
            },
            Fp8Format::E5M2 => FormatSpec {
                sym_shift: 2,
                sign_mask: 0x04,
                sign_shift: 5,
                low_mask: 0x03,
            },
        }
    }

    #[inline(always)]
    fn splat4(mask: u8) -> u32 {
        u32::from_ne_bytes([mask; 4])
    }
}

/// Spread 4 MSB-first rest nibbles into the low nibble of 4 byte lanes
/// (lane k = nibble k, i.e. lane 0 gets the *most significant* nibble,
/// matching stream order).
#[inline(always)]
pub fn spread_rests(rests: u16) -> u32 {
    let r = rests as u32;
    (r >> 12) | (r & 0x0F00) | ((r & 0x00F0) << 12) | ((r & 0x000F) << 24)
}

/// Assemble 4 FP8 bytes from one full-count [`MultiLut`] entry's byte
/// lanes and the next 16 bits of the packed nibble plane. Byte `k` of the
/// returned array is output element `k`. Portable SWAR; every tier uses
/// this for sub-16-byte work.
#[inline(always)]
pub fn assemble4(spec: FormatSpec, sym_bytes: u32, rests: u16) -> [u8; 4] {
    let sp = spread_rests(rests);
    let sign = (sp & FormatSpec::splat4(spec.sign_mask)) << spec.sign_shift;
    // syms < 32 ⇒ the lane shift stays inside each byte
    let mid = sym_bytes << spec.sym_shift;
    let low = sp & FormatSpec::splat4(spec.low_mask);
    (sign | mid | low).to_le_bytes()
}

/// Assemble 16 FP8 bytes from four consecutive full-count entries and 64
/// bits of the packed nibble plane. `rests[g]` carries the nibbles of
/// output bytes `4g .. 4g+4` (MSB-first, stream order). Dispatches to the
/// compiled tier; bit-identical to four [`assemble4`] calls by
/// construction (and by test on every tier).
#[inline(always)]
pub fn assemble16(spec: FormatSpec, sym_words: &[u32; 4], rests: &[u16; 4], out: &mut [u8; 16]) {
    imp::assemble16(spec, sym_words, rests, out)
}

/// Portable reference kernels — `assemble16` as four SWAR `assemble4`s.
/// Always compiled so the SIMD tiers can be differential-tested against
/// it on their own hardware.
pub mod portable {
    use super::{assemble4, FormatSpec};

    #[inline(always)]
    pub fn assemble16(
        spec: FormatSpec,
        sym_words: &[u32; 4],
        rests: &[u16; 4],
        out: &mut [u8; 16],
    ) {
        for g in 0..4 {
            out[4 * g..4 * g + 4].copy_from_slice(&assemble4(spec, sym_words[g], rests[g]));
        }
    }
}

#[cfg(any(
    feature = "force-swar",
    not(any(target_arch = "x86_64", target_arch = "aarch64"))
))]
use self::portable as imp;

#[cfg(all(not(feature = "force-swar"), target_arch = "x86_64"))]
mod imp {
    use super::FormatSpec;
    use core::arch::x86_64::*;

    /// SSE2 (x86_64 baseline — no runtime detection needed): one 16-byte
    /// store per 16 outputs. Variable-count shifts (`_mm_sll_epi16`) keep
    /// the kernel format-generic without const-generic plumbing.
    #[inline(always)]
    pub fn assemble16(
        spec: FormatSpec,
        sym_words: &[u32; 4],
        rests: &[u16; 4],
        out: &mut [u8; 16],
    ) {
        // Big-endian concatenation: byte j of `nib` holds the rests of
        // output bytes 2j (high nibble) and 2j+1 (low nibble).
        let nib: [u8; 8] = (((rests[0] as u64) << 48)
            | ((rests[1] as u64) << 32)
            | ((rests[2] as u64) << 16)
            | rests[3] as u64)
            .to_be_bytes();
        // SAFETY: SSE2 is unconditionally available on x86_64; all loads
        // and stores are unaligned-tolerant (`loadl`/`loadu`/`storeu`)
        // over properly sized Rust arrays.
        unsafe {
            let v = _mm_loadl_epi64(nib.as_ptr() as *const __m128i);
            let x0f = _mm_set1_epi8(0x0F);
            // even nibbles (outputs 0,2,..) and odd nibbles (1,3,..),
            // interleaved back into stream order: byte k = rest of out k
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(v), x0f);
            let lo = _mm_and_si128(v, x0f);
            let sp = _mm_unpacklo_epi8(hi, lo);

            // [u32; 4] in memory is exactly byte lanes 0..16 of the syms
            let syms = _mm_loadu_si128(sym_words.as_ptr() as *const __m128i);
            let sign_shift = _mm_cvtsi32_si128(spec.sign_shift as i32);
            let sym_shift = _mm_cvtsi32_si128(spec.sym_shift as i32);
            // masked operands keep the epi16 shifts from bleeding across
            // byte lanes: sign bits ≤ bit 3 shifted ≤ 5, syms < 32
            let sign = _mm_sll_epi16(
                _mm_and_si128(sp, _mm_set1_epi8(spec.sign_mask as i8)),
                sign_shift,
            );
            let mid = _mm_sll_epi16(syms, sym_shift);
            let low = _mm_and_si128(sp, _mm_set1_epi8(spec.low_mask as i8));
            let assembled = _mm_or_si128(_mm_or_si128(sign, mid), low);
            _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, assembled);
        }
    }
}

#[cfg(all(not(feature = "force-swar"), target_arch = "aarch64"))]
mod imp {
    use super::FormatSpec;
    use core::arch::aarch64::*;

    /// NEON (aarch64 baseline): mirror of the SSE2 kernel. `vshlq_u8`
    /// with a splatted signed count is the variable per-byte shift.
    #[inline(always)]
    pub fn assemble16(
        spec: FormatSpec,
        sym_words: &[u32; 4],
        rests: &[u16; 4],
        out: &mut [u8; 16],
    ) {
        let nib: [u8; 8] = (((rests[0] as u64) << 48)
            | ((rests[1] as u64) << 32)
            | ((rests[2] as u64) << 16)
            | rests[3] as u64)
            .to_be_bytes();
        // SAFETY: NEON is unconditionally available on aarch64; loads and
        // stores are over properly sized Rust arrays.
        unsafe {
            let v = vld1_u8(nib.as_ptr());
            let x0f = vdup_n_u8(0x0F);
            let hi = vand_u8(vshl_u8(v, vdup_n_s8(-4)), x0f);
            let lo = vand_u8(v, x0f);
            // interleave high/low nibbles back into stream order
            let sp = vcombine_u8(vzip1_u8(hi, lo), vzip2_u8(hi, lo));

            let syms = vld1q_u8(sym_words.as_ptr() as *const u8);
            let sign = vshlq_u8(
                vandq_u8(sp, vdupq_n_u8(spec.sign_mask)),
                vdupq_n_s8(spec.sign_shift as i8),
            );
            let mid = vshlq_u8(syms, vdupq_n_s8(spec.sym_shift as i8));
            let low = vandq_u8(sp, vdupq_n_u8(spec.low_mask));
            let assembled = vorrq_u8(vorrq_u8(sign, mid), low);
            vst1q_u8(out.as_mut_ptr(), assembled);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn scalar_reference(
        spec: FormatSpec,
        format: Fp8Format,
        sym_bytes: u32,
        rests: u16,
    ) -> [u8; 4] {
        let mut out = [0u8; 4];
        for (k, slot) in out.iter_mut().enumerate() {
            let sym = (sym_bytes >> (8 * k)) as u8;
            let rest = ((rests >> (12 - 4 * k)) & 0x0F) as u8;
            *slot = format.assemble(sym, rest);
        }
        let _ = spec;
        out
    }

    fn random_sym_word(rng: &mut Xoshiro256, format: Fp8Format) -> u32 {
        let cap = format.alphabet_size() as u64;
        let mut w = 0u32;
        for k in 0..4 {
            w |= (rng.next_below(cap) as u32) << (8 * k);
        }
        w
    }

    #[test]
    fn assemble4_matches_scalar_exhaustive_rests() {
        let mut rng = Xoshiro256::seed_from_u64(41);
        for format in [Fp8Format::E4M3, Fp8Format::E5M2] {
            let spec = FormatSpec::of(format);
            for r in 0..=u16::MAX {
                let sw = random_sym_word(&mut rng, format);
                assert_eq!(
                    assemble4(spec, sw, r),
                    scalar_reference(spec, format, sw, r),
                    "format {format:?} rests {r:#06x} syms {sw:#010x}"
                );
            }
        }
    }

    #[test]
    fn assemble16_matches_portable_and_scalar() {
        let mut rng = Xoshiro256::seed_from_u64(42);
        for format in [Fp8Format::E4M3, Fp8Format::E5M2] {
            let spec = FormatSpec::of(format);
            for _ in 0..20_000 {
                let sym_words = [
                    random_sym_word(&mut rng, format),
                    random_sym_word(&mut rng, format),
                    random_sym_word(&mut rng, format),
                    random_sym_word(&mut rng, format),
                ];
                let rests = [
                    rng.next_u64() as u16,
                    rng.next_u64() as u16,
                    rng.next_u64() as u16,
                    rng.next_u64() as u16,
                ];
                let mut tier = [0u8; 16];
                let mut swar = [0u8; 16];
                assemble16(spec, &sym_words, &rests, &mut tier);
                portable::assemble16(spec, &sym_words, &rests, &mut swar);
                assert_eq!(tier, swar, "tier {TIER} diverges from portable SWAR");
                for g in 0..4 {
                    assert_eq!(
                        &tier[4 * g..4 * g + 4],
                        &scalar_reference(spec, format, sym_words[g], rests[g]),
                        "group {g}"
                    );
                }
            }
        }
    }

    #[test]
    fn spread_rests_lane_mapping() {
        // nibble 0 (most significant) lands in byte lane 0
        assert_eq!(spread_rests(0xABCD).to_le_bytes(), [0x0A, 0x0B, 0x0C, 0x0D]);
        assert_eq!(spread_rests(0x0000), 0);
        assert_eq!(spread_rests(0xFFFF), 0x0F0F0F0F);
    }

    #[test]
    fn tier_is_named() {
        assert!(["sse2", "neon", "swar64"].contains(&TIER));
    }
}
