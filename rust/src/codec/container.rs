//! On-disk / wire containers for ECF8 artifacts: the legacy v1 one-blob
//! format, and the v2 sharded record format behind the codec registry.
//!
//! ## v1 — one `.ecf8` file per tensor (little-endian)
//!
//! ```text
//! 0    magic "ECF8"            4 bytes
//! 4    version                 u16
//! 6    format                  u8   (0 = E4M3, 1 = E5M2)
//! 7    alphabet                u8
//! 8    n_elem                  u64
//! 16   bytes_per_thread (B)    u32
//! 20   threads_per_block (T)   u32
//! 24   n_blocks                u64
//! 32   encoded_bits            u64
//! 40   encoded_len             u64  (padded length actually stored)
//! 48   packed_len              u64
//! 56   gaps_len                u64
//! 64   payload_crc32           u32
//! 68   reserved                4 bytes
//! 72   code_lengths            `alphabet` bytes
//! ..   outpos                  (n_blocks+1) × u64
//! ..   gaps                    gaps_len bytes
//! ..   packed                  packed_len bytes
//! ..   encoded                 encoded_len bytes
//! ```
//!
//! ## v2 — sharded model artifact with a binary tensor index
//!
//! A v2 model is a directory:
//!
//! ```text
//! <model>/
//!   index.ecf8i        binary tensor index (written last, CRC-trailed)
//!   shard-0000.ecf8s   records back to back behind an 8-byte header
//!   shard-0001.ecf8s   ...
//! ```
//!
//! Shard header: `magic "ECS8" (4) | version u16 | shard_index u16`.
//!
//! Record — every tensor is independently decodable from its record
//! alone (the header names the codec; the payload carries a CRC):
//!
//! ```text
//! 0    magic "ECR8"    4 bytes
//! 4    codec           u8   (CodecId — see codec::codecs)
//! 5    format          u8   (Fp8Format)
//! 6    flags           u16  (reserved, 0)
//! 8    n_elem          u64
//! 16   payload_len     u64
//! 24   payload_crc32   u32
//! 28   reserved        u32
//! 32   payload         payload_len bytes
//! ```
//!
//! Index: a fixed header, one entry per tensor (shape/role metadata plus
//! the record's shard/offset/len and payload CRC), and a trailing CRC-32
//! of every preceding byte. See [`TensorIndex`].
//!
//! Writers stream through [`std::io::Write`] ([`serialize_into`],
//! [`ShardWriter`]); nothing larger than one tensor's payload is ever
//! buffered. Readers operate on byte slices so callers can feed them
//! from files, mmaps, or in-memory stores.

use super::{Ecf8Blob, Ecf8Params, Fp8Format};
use crate::util::mmap::ByteView;
use std::io::Write;

pub const MAGIC: &[u8; 4] = b"ECF8";
pub const VERSION: u16 = 1;
/// Fixed header size (pre-code_lengths), for size accounting.
pub const HEADER_BYTES: usize = 72;

pub const SHARD_MAGIC: &[u8; 4] = b"ECS8";
pub const RECORD_MAGIC: &[u8; 4] = b"ECR8";
pub const INDEX_MAGIC: &[u8; 4] = b"ECI8";
pub const V2_VERSION: u16 = 2;
/// Current index version: v3 appends the per-layer extent table (the
/// layer-contiguous placement record) after the entries; v2 indexes
/// (no extents) remain readable.
pub const INDEX_VERSION: u16 = 3;
pub const SHARD_HEADER_BYTES: usize = 8;
pub const RECORD_HEADER_BYTES: usize = 32;

/// File name of the v2 binary tensor index inside a model directory.
pub const INDEX_FILE: &str = "index.ecf8i";

/// File name of shard `i` inside a model directory.
pub fn shard_file_name(i: u32) -> String {
    format!("shard-{i:04}.ecf8s")
}

#[derive(Debug)]
pub enum ContainerError {
    BadMagic,
    BadVersion(u16),
    BadFormat(u8),
    Truncated { need: usize, have: usize },
    CrcMismatch { stored: u32, computed: u32 },
    Inconsistent(&'static str),
}

impl std::fmt::Display for ContainerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ContainerError::BadMagic => write!(f, "bad magic (not an ECF8 container)"),
            ContainerError::BadVersion(v) => write!(f, "unsupported version {v}"),
            ContainerError::BadFormat(b) => write!(f, "unknown format byte {b}"),
            ContainerError::Truncated { need, have } => {
                write!(f, "container truncated: need {need} bytes, have {have}")
            }
            ContainerError::CrcMismatch { stored, computed } => write!(
                f,
                "payload CRC mismatch (stored {stored:#010x}, computed {computed:#010x})"
            ),
            ContainerError::Inconsistent(what) => write!(f, "inconsistent metadata: {what}"),
        }
    }
}

impl std::error::Error for ContainerError {}

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ContainerError> {
        // checked: `n` may come from an untrusted length field
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| ContainerError::Truncated {
                need: usize::MAX,
                have: self.data.len(),
            })?;
        if end > self.data.len() {
            return Err(ContainerError::Truncated {
                need: end,
                have: self.data.len(),
            });
        }
        let s = &self.data[self.pos..end];
        self.pos = end;
        Ok(s)
    }
    fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }
    fn u16(&mut self) -> Result<u16, ContainerError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, ContainerError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, ContainerError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn u8(&mut self) -> Result<u8, ContainerError> {
        Ok(self.take(1)?[0])
    }
}

/// Exact byte length [`serialize`] / [`serialize_into`] will produce.
pub fn serialized_len(blob: &Ecf8Blob) -> usize {
    HEADER_BYTES
        + blob.code_lengths.len()
        + blob.outpos.len() * 8
        + blob.gaps.len()
        + blob.packed.len()
        + blob.encoded.len()
}

/// Stream a blob's container bytes into `w` (wrap file handles in a
/// `BufWriter`; the per-field writes are small).
pub fn serialize_into<W: Write>(blob: &Ecf8Blob, w: &mut W) -> std::io::Result<()> {
    let alphabet = blob.format.alphabet_size();
    assert_eq!(blob.code_lengths.len(), alphabet);
    let mut crc = crate::util::crc32::Hasher::new();
    crc.update(&blob.packed);
    crc.update(&blob.encoded);
    crc.update(&blob.gaps);
    let crc = crc.finalize();

    let mut head = Vec::with_capacity(HEADER_BYTES);
    head.extend_from_slice(MAGIC);
    put_u16(&mut head, VERSION);
    head.push(blob.format as u8);
    head.push(alphabet as u8);
    put_u64(&mut head, blob.n_elem as u64);
    put_u32(&mut head, blob.params.bytes_per_thread as u32);
    put_u32(&mut head, blob.params.threads_per_block as u32);
    put_u64(&mut head, blob.n_blocks() as u64);
    put_u64(&mut head, blob.encoded_bits);
    put_u64(&mut head, blob.encoded.len() as u64);
    put_u64(&mut head, blob.packed.len() as u64);
    put_u64(&mut head, blob.gaps.len() as u64);
    put_u32(&mut head, crc);
    head.extend_from_slice(&[0u8; 4]); // reserved
    debug_assert_eq!(head.len(), HEADER_BYTES);
    w.write_all(&head)?;
    w.write_all(&blob.code_lengths)?;
    for &p in &blob.outpos {
        w.write_all(&p.to_le_bytes())?;
    }
    w.write_all(&blob.gaps)?;
    w.write_all(&blob.packed)?;
    w.write_all(&blob.encoded)?;
    Ok(())
}

/// Serialize a blob to container bytes.
pub fn serialize(blob: &Ecf8Blob) -> Vec<u8> {
    let mut out = Vec::with_capacity(serialized_len(blob));
    serialize_into(blob, &mut out).expect("Vec<u8> writes are infallible");
    out
}

/// Deserialize container bytes back into a blob (validates CRC and
/// internal consistency). Copies the input once to own the streams;
/// callers that already hold a [`ByteView`] (mapped shards, whole-file
/// reads) should use [`deserialize_view`] / [`deserialize_owned`], which
/// share the backing instead.
pub fn deserialize(data: &[u8]) -> Result<Ecf8Blob, ContainerError> {
    deserialize_view(&ByteView::from_vec(data.to_vec()))
}

/// [`deserialize`] taking ownership of the buffer — zero extra copies
/// (the blob's stream views share the one allocation).
pub fn deserialize_owned(data: Vec<u8>) -> Result<Ecf8Blob, ContainerError> {
    deserialize_view(&ByteView::from_vec(data))
}

/// Zero-copy deserialize: the returned blob's `encoded`/`packed`/`gaps`
/// are sub-views of `src` (small metadata — code lengths, outpos — is
/// parsed out). This is the mmap serving path: a blob parsed from a
/// mapped shard record decodes directly out of the page cache.
pub fn deserialize_view(src: &ByteView) -> Result<Ecf8Blob, ContainerError> {
    let data = src.as_slice();
    let mut c = Cursor { data, pos: 0 };
    if c.take(4)? != MAGIC {
        return Err(ContainerError::BadMagic);
    }
    let version = c.u16()?;
    if version != VERSION {
        return Err(ContainerError::BadVersion(version));
    }
    let format = Fp8Format::from_u8(c.u8()?).ok_or(ContainerError::BadFormat(255))?;
    let alphabet = c.u8()? as usize;
    if alphabet != format.alphabet_size() {
        return Err(ContainerError::Inconsistent("alphabet size vs format"));
    }
    let n_elem = c.u64()? as usize;
    let bytes_per_thread = c.u32()? as usize;
    let threads_per_block = c.u32()? as usize;
    let n_blocks = c.u64()? as usize;
    let encoded_bits = c.u64()?;
    let encoded_len = c.u64()? as usize;
    let packed_len = c.u64()? as usize;
    let gaps_len = c.u64()? as usize;
    let stored_crc = c.u32()?;
    let _reserved = c.take(4)?;
    let code_lengths = c.take(alphabet)?.to_vec();
    // cap the pre-allocation by what the input could actually hold, so a
    // corrupt n_blocks cannot trigger a huge allocation (or an overflow
    // in `n_blocks + 1`) before the cursor reports Truncated
    let mut outpos = Vec::with_capacity(n_blocks.min(c.remaining() / 8) + 1);
    for _ in 0..=n_blocks {
        outpos.push(c.u64()?);
    }
    // the three streams become sub-views of `src` — no copies; `take`
    // supplies the bounds checking, the cursor position the offsets
    let gaps_start = c.pos;
    let gaps = c.take(gaps_len)?;
    let packed_start = c.pos;
    let packed = c.take(packed_len)?;
    let encoded_start = c.pos;
    let encoded = c.take(encoded_len)?;

    let mut crc = crate::util::crc32::Hasher::new();
    crc.update(packed);
    crc.update(encoded);
    crc.update(gaps);
    let computed = crc.finalize();
    if computed != stored_crc {
        return Err(ContainerError::CrcMismatch {
            stored: stored_crc,
            computed,
        });
    }

    let params = Ecf8Params {
        bytes_per_thread,
        threads_per_block,
    };
    if encoded_len != n_blocks * params.block_bytes() + 8 {
        return Err(ContainerError::Inconsistent("encoded length vs geometry"));
    }
    if packed_len != n_elem.div_ceil(2) {
        return Err(ContainerError::Inconsistent("packed length vs n_elem"));
    }
    if outpos.last().copied() != Some(n_elem as u64) {
        return Err(ContainerError::Inconsistent("outpos tail vs n_elem"));
    }

    Ok(Ecf8Blob {
        format,
        params,
        n_elem,
        code_lengths,
        encoded: src.slice(encoded_start..encoded_start + encoded_len),
        encoded_bits,
        packed: src.slice(packed_start..packed_start + packed_len),
        gaps: src.slice(gaps_start..gaps_start + gaps_len),
        outpos,
    })
}

/// Write a blob to a file (streamed through a `BufWriter` — no
/// whole-container `Vec<u8>` round-trip).
pub fn write_file(blob: &Ecf8Blob, path: &std::path::Path) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    serialize_into(blob, &mut w)?;
    w.flush()
}

/// Read a blob from a file (one read; the blob's streams share the
/// buffer).
pub fn read_file(path: &std::path::Path) -> anyhow::Result<Ecf8Blob> {
    let data = std::fs::read(path)?;
    Ok(deserialize_owned(data)?)
}

// ---------------------------------------------------------------------------
// Container v2: sharded tensor records + binary index
// ---------------------------------------------------------------------------

/// Header of one v2 tensor record (see the module docs for the layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecordHeader {
    /// codec id byte (see `codec::codecs::CodecId`)
    pub codec: u8,
    /// FP8 format byte (see [`Fp8Format::from_u8`])
    pub format: u8,
    pub n_elem: u64,
    pub payload_len: u64,
    pub payload_crc: u32,
}

impl RecordHeader {
    pub fn write_into<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        let mut head = [0u8; RECORD_HEADER_BYTES];
        head[0..4].copy_from_slice(RECORD_MAGIC);
        head[4] = self.codec;
        head[5] = self.format;
        // [6..8] flags, reserved
        head[8..16].copy_from_slice(&self.n_elem.to_le_bytes());
        head[16..24].copy_from_slice(&self.payload_len.to_le_bytes());
        head[24..28].copy_from_slice(&self.payload_crc.to_le_bytes());
        // [28..32] reserved
        w.write_all(&head)
    }

    pub fn parse(data: &[u8]) -> Result<Self, ContainerError> {
        let mut c = Cursor { data, pos: 0 };
        if c.take(4)? != RECORD_MAGIC {
            return Err(ContainerError::BadMagic);
        }
        let codec = c.u8()?;
        let format = c.u8()?;
        let _flags = c.u16()?;
        let n_elem = c.u64()?;
        let payload_len = c.u64()?;
        let payload_crc = c.u32()?;
        let _reserved = c.u32()?;
        Ok(Self {
            codec,
            format,
            n_elem,
            payload_len,
            payload_crc,
        })
    }

    /// Total record length (header + payload).
    pub fn record_len(&self) -> u64 {
        RECORD_HEADER_BYTES as u64 + self.payload_len
    }
}

/// Parse one record from the start of `data`: header + CRC-verified
/// payload slice.
pub fn read_record(data: &[u8]) -> Result<(RecordHeader, &[u8]), ContainerError> {
    let h = RecordHeader::parse(data)?;
    let plen = usize::try_from(h.payload_len).map_err(|_| ContainerError::Truncated {
        need: usize::MAX,
        have: data.len(),
    })?;
    let end = RECORD_HEADER_BYTES
        .checked_add(plen)
        .ok_or_else(|| ContainerError::Truncated {
            need: usize::MAX,
            have: data.len(),
        })?;
    if end > data.len() {
        return Err(ContainerError::Truncated {
            need: end,
            have: data.len(),
        });
    }
    let payload = &data[RECORD_HEADER_BYTES..end];
    let computed = crate::util::crc32::crc32(payload);
    if computed != h.payload_crc {
        return Err(ContainerError::CrcMismatch {
            stored: h.payload_crc,
            computed,
        });
    }
    Ok((h, payload))
}

/// [`read_record`] over a [`ByteView`] positioned at a record start: the
/// returned payload is a sub-view sharing `src`'s backing (for a mapped
/// shard, a window straight into the page cache). CRC-verified like the
/// slice reader.
pub fn read_record_view(src: &ByteView) -> Result<(RecordHeader, ByteView), ContainerError> {
    let (header, payload) = read_record(src.as_slice())?;
    let start = RECORD_HEADER_BYTES;
    Ok((header, src.slice(start..start + payload.len())))
}

/// Validate an in-memory shard image's 8-byte header; returns the shard
/// index it claims.
pub fn parse_shard_header(data: &[u8]) -> Result<u16, ContainerError> {
    let mut c = Cursor { data, pos: 0 };
    if c.take(4)? != SHARD_MAGIC {
        return Err(ContainerError::BadMagic);
    }
    let v = c.u16()?;
    if v != V2_VERSION {
        return Err(ContainerError::BadVersion(v));
    }
    c.u16()
}

/// Walk every record of an in-memory shard image in order, CRC-checking
/// each payload — the index-free inspection/recovery scan. Returns each
/// record's header and the byte range of its payload within `data`.
pub fn walk_shard(
    data: &[u8],
) -> Result<Vec<(RecordHeader, std::ops::Range<usize>)>, ContainerError> {
    parse_shard_header(data)?;
    let mut pos = SHARD_HEADER_BYTES;
    let mut out = Vec::new();
    while pos < data.len() {
        let (h, payload) = read_record(&data[pos..])?;
        let start = pos + RECORD_HEADER_BYTES;
        out.push((h, start..start + payload.len()));
        pos = start + payload.len();
    }
    Ok(out)
}

/// Where a record landed inside its shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecordLocation {
    /// byte offset of the record header within the shard file
    pub offset: u64,
    /// total record length (header + payload)
    pub len: u64,
    pub payload_crc: u32,
}

/// Streaming writer for one `.ecf8s` shard: records are appended through
/// a buffered file handle, so nothing larger than one tensor's payload is
/// ever resident.
pub struct ShardWriter {
    w: std::io::BufWriter<std::fs::File>,
    bytes: u64,
}

impl ShardWriter {
    pub fn create(path: &std::path::Path, shard_index: u16) -> std::io::Result<Self> {
        let f = std::fs::File::create(path)?;
        let mut w = std::io::BufWriter::new(f);
        w.write_all(SHARD_MAGIC)?;
        w.write_all(&V2_VERSION.to_le_bytes())?;
        w.write_all(&shard_index.to_le_bytes())?;
        Ok(Self {
            w,
            bytes: SHARD_HEADER_BYTES as u64,
        })
    }

    /// Append one record; returns where it landed.
    pub fn append(
        &mut self,
        codec: u8,
        format: u8,
        n_elem: u64,
        payload: &[u8],
    ) -> std::io::Result<RecordLocation> {
        let payload_crc = crate::util::crc32::crc32(payload);
        let header = RecordHeader {
            codec,
            format,
            n_elem,
            payload_len: payload.len() as u64,
            payload_crc,
        };
        let offset = self.bytes;
        header.write_into(&mut self.w)?;
        self.w.write_all(payload)?;
        self.bytes += header.record_len();
        Ok(RecordLocation {
            offset,
            len: header.record_len(),
            payload_crc,
        })
    }

    /// Bytes written so far (header included) — the shard-rollover gauge.
    pub fn bytes_written(&self) -> u64 {
        self.bytes
    }

    /// Flush and close; returns the final shard size.
    pub fn finish(mut self) -> std::io::Result<u64> {
        self.w.flush()?;
        Ok(self.bytes)
    }
}

/// One tensor's entry in the v2 binary index: shape/role metadata (what
/// the v1 plain-text manifest carried) plus the record's location and
/// payload CRC.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexEntry {
    pub name: String,
    pub rows: u64,
    pub cols: u64,
    pub layer: u32,
    /// `BlockType` code (see `model::config::BlockType::from_code`)
    pub block_type: u8,
    /// codec id byte (see `codec::codecs::CodecId`)
    pub codec: u8,
    /// FP8 format byte
    pub format: u8,
    pub shard: u32,
    pub offset: u64,
    pub len: u64,
    pub payload_crc: u32,
}

impl IndexEntry {
    /// Element count; saturates on a crafted rows×cols overflow (the
    /// saturated value then fails the record-header cross-check instead
    /// of panicking in debug builds).
    pub fn n_elem(&self) -> u64 {
        self.rows.saturating_mul(self.cols)
    }
}

/// One transformer layer's contiguous byte range inside a shard — the
/// placement record that lets readers fetch (or `madvise`) a whole layer
/// as one extent. Only layers whose records landed contiguously in a
/// single shard get an extent; `offset`/`len` cover the records
/// (headers included) back to back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerExtent {
    pub layer: u32,
    pub shard: u32,
    pub offset: u64,
    pub len: u64,
}

impl LayerExtent {
    pub fn end(&self) -> u64 {
        self.offset + self.len
    }
}

/// The v2 binary tensor index: the decode plan for a sharded model
/// artifact. Serialized with a trailing CRC-32 over every preceding byte.
/// Since index v3 it also records [`LayerExtent`]s for layers the writer
/// placed contiguously.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TensorIndex {
    pub model: String,
    pub n_shards: u32,
    pub entries: Vec<IndexEntry>,
    /// per-layer contiguous placement (empty for v2 indexes and for
    /// interleaved layouts)
    pub layer_extents: Vec<LayerExtent>,
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    assert!(s.len() <= u16::MAX as usize, "name too long for index");
    put_u16(buf, s.len() as u16);
    buf.extend_from_slice(s.as_bytes());
}

fn read_str(c: &mut Cursor<'_>) -> Result<String, ContainerError> {
    let len = c.u16()? as usize;
    let bytes = c.take(len)?;
    String::from_utf8(bytes.to_vec())
        .map_err(|_| ContainerError::Inconsistent("non-UTF-8 name in index"))
}

impl TensorIndex {
    /// Total stored bytes across all records (headers included).
    pub fn stored_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.len).sum()
    }

    /// Total raw FP8 bytes the records decode to.
    pub fn raw_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.n_elem()).sum()
    }

    /// Extent of transformer layer `layer`, when the writer placed it
    /// contiguously.
    pub fn layer_extent(&self, layer: u32) -> Option<&LayerExtent> {
        self.layer_extents.iter().find(|e| e.layer == layer)
    }

    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(INDEX_MAGIC);
        put_u16(&mut out, INDEX_VERSION);
        put_u16(&mut out, 0); // flags
        put_u32(&mut out, self.n_shards);
        put_u32(&mut out, self.entries.len() as u32);
        put_str(&mut out, &self.model);
        for e in &self.entries {
            put_str(&mut out, &e.name);
            put_u64(&mut out, e.rows);
            put_u64(&mut out, e.cols);
            put_u32(&mut out, e.layer);
            out.push(e.block_type);
            out.push(e.codec);
            out.push(e.format);
            out.push(0); // reserved
            put_u32(&mut out, e.shard);
            put_u64(&mut out, e.offset);
            put_u64(&mut out, e.len);
            put_u32(&mut out, e.payload_crc);
        }
        // v3 extent table
        put_u32(&mut out, self.layer_extents.len() as u32);
        for x in &self.layer_extents {
            put_u32(&mut out, x.layer);
            put_u32(&mut out, x.shard);
            put_u64(&mut out, x.offset);
            put_u64(&mut out, x.len);
        }
        let crc = crate::util::crc32::crc32(&out);
        put_u32(&mut out, crc);
        out
    }

    pub fn deserialize(data: &[u8]) -> Result<Self, ContainerError> {
        let mut c = Cursor { data, pos: 0 };
        if c.take(4)? != INDEX_MAGIC {
            return Err(ContainerError::BadMagic);
        }
        let version = c.u16()?;
        if version != V2_VERSION && version != INDEX_VERSION {
            return Err(ContainerError::BadVersion(version));
        }
        let _flags = c.u16()?;
        let n_shards = c.u32()?;
        let n_tensors = c.u32()? as usize;
        let model = read_str(&mut c)?;
        // entries are ≥ 50 bytes each; cap pre-allocation by the input
        let mut entries = Vec::with_capacity(n_tensors.min(c.remaining() / 50 + 1));
        for _ in 0..n_tensors {
            let name = read_str(&mut c)?;
            let rows = c.u64()?;
            let cols = c.u64()?;
            let layer = c.u32()?;
            let block_type = c.u8()?;
            let codec = c.u8()?;
            let format = c.u8()?;
            let _reserved = c.u8()?;
            let shard = c.u32()?;
            let offset = c.u64()?;
            let len = c.u64()?;
            let payload_crc = c.u32()?;
            entries.push(IndexEntry {
                name,
                rows,
                cols,
                layer,
                block_type,
                codec,
                format,
                shard,
                offset,
                len,
                payload_crc,
            });
        }
        let mut layer_extents = Vec::new();
        if version >= INDEX_VERSION {
            let n_extents = c.u32()? as usize;
            // extents are 24 bytes each; cap pre-allocation by the input
            layer_extents.reserve(n_extents.min(c.remaining() / 24 + 1));
            for _ in 0..n_extents {
                let layer = c.u32()?;
                let shard = c.u32()?;
                let offset = c.u64()?;
                let len = c.u64()?;
                layer_extents.push(LayerExtent {
                    layer,
                    shard,
                    offset,
                    len,
                });
            }
        }
        let body_end = c.pos;
        let stored = c.u32()?;
        let computed = crate::util::crc32::crc32(&data[..body_end]);
        if stored != computed {
            return Err(ContainerError::CrcMismatch { stored, computed });
        }
        if c.remaining() != 0 {
            return Err(ContainerError::Inconsistent("trailing bytes after index"));
        }
        Ok(Self {
            model,
            n_shards,
            entries,
            layer_extents,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::encode::encode;
    use crate::util::prng::Xoshiro256;

    fn sample_blob(n: usize) -> Ecf8Blob {
        let mut rng = Xoshiro256::seed_from_u64(77);
        let data: Vec<u8> = (0..n)
            .map(|_| {
                let x = (crate::util::sampling::normal(&mut rng) * 0.05) as f32;
                crate::fp8::F8E4M3::from_f32(x).to_bits()
            })
            .collect();
        encode(&data, Fp8Format::E4M3, Ecf8Params::default())
    }

    #[test]
    fn serialize_roundtrip() {
        let blob = sample_blob(12_345);
        let bytes = serialize(&blob);
        let back = deserialize(&bytes).unwrap();
        assert_eq!(back.n_elem, blob.n_elem);
        assert_eq!(back.encoded, blob.encoded);
        assert_eq!(back.packed, blob.packed);
        assert_eq!(back.gaps, blob.gaps);
        assert_eq!(back.outpos, blob.outpos);
        assert_eq!(back.code_lengths, blob.code_lengths);
        assert_eq!(back.format, blob.format);
        // and it still decodes losslessly
        let a = crate::codec::decompress_fp8(&blob);
        let b = crate::codec::decompress_fp8(&back);
        assert_eq!(a, b);
    }

    #[test]
    fn detects_corruption() {
        let blob = sample_blob(5000);
        let mut bytes = serialize(&blob);
        let n = bytes.len();
        bytes[n - 100] ^= 0xFF; // flip payload bits
        assert!(matches!(
            deserialize(&bytes),
            Err(ContainerError::CrcMismatch { .. })
        ));
    }

    #[test]
    fn detects_truncation() {
        let blob = sample_blob(5000);
        let bytes = serialize(&blob);
        assert!(matches!(
            deserialize(&bytes[..bytes.len() - 9]),
            Err(ContainerError::Truncated { .. })
        ));
        assert!(matches!(
            deserialize(&bytes[..30]),
            Err(ContainerError::Truncated { .. })
        ));
    }

    #[test]
    fn detects_bad_magic_and_version() {
        let blob = sample_blob(100);
        let mut bytes = serialize(&blob);
        bytes[0] = b'X';
        assert!(matches!(deserialize(&bytes), Err(ContainerError::BadMagic)));
        let mut bytes = serialize(&blob);
        bytes[4] = 99;
        assert!(matches!(
            deserialize(&bytes),
            Err(ContainerError::BadVersion(_))
        ));
    }

    #[test]
    fn file_roundtrip() {
        let blob = sample_blob(2000);
        let dir = std::env::temp_dir().join("ecf8_container_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ecf8");
        write_file(&blob, &path).unwrap();
        let back = read_file(&path).unwrap();
        assert_eq!(
            crate::codec::decompress_fp8(&back),
            crate::codec::decompress_fp8(&blob)
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_overhead_is_small() {
        let blob = sample_blob(1_000_000);
        let bytes = serialize(&blob);
        let payload = blob.encoded.len() + blob.packed.len() + blob.gaps.len();
        // metadata overhead < 2% for MB-scale tensors
        assert!((bytes.len() - payload) as f64 / (bytes.len() as f64) < 0.02);
    }

    #[test]
    fn serialized_len_matches_serialize() {
        for n in [0usize, 1, 4097, 123_456] {
            let blob = sample_blob(n);
            assert_eq!(serialize(&blob).len(), serialized_len(&blob), "n={n}");
        }
    }

    #[test]
    fn record_roundtrip_and_crc() {
        let payload = b"some codec payload bytes".to_vec();
        let mut buf = Vec::new();
        let crc = crate::util::crc32::crc32(&payload);
        let h = RecordHeader {
            codec: 1,
            format: 0,
            n_elem: 24,
            payload_len: payload.len() as u64,
            payload_crc: crc,
        };
        h.write_into(&mut buf).unwrap();
        buf.extend_from_slice(&payload);
        let (back, p) = read_record(&buf).unwrap();
        assert_eq!(back, h);
        assert_eq!(p, &payload[..]);
        // flipped payload bit => CrcMismatch
        let mut bad = buf.clone();
        let n = bad.len();
        bad[n - 3] ^= 0x10;
        assert!(matches!(
            read_record(&bad),
            Err(ContainerError::CrcMismatch { .. })
        ));
        // truncated payload => Truncated
        assert!(matches!(
            read_record(&buf[..buf.len() - 1]),
            Err(ContainerError::Truncated { .. })
        ));
    }

    #[test]
    fn shard_write_walk_roundtrip() {
        let dir = std::env::temp_dir().join("ecf8_shard_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(shard_file_name(0));
        let mut w = ShardWriter::create(&path, 0).unwrap();
        let a = w.append(1, 0, 3, b"abc").unwrap();
        let b = w.append(1, 0, 5, b"defgh").unwrap();
        assert_eq!(a.offset, SHARD_HEADER_BYTES as u64);
        assert_eq!(b.offset, a.offset + a.len);
        let total = w.finish().unwrap();
        let data = std::fs::read(&path).unwrap();
        assert_eq!(data.len() as u64, total);
        assert_eq!(parse_shard_header(&data).unwrap(), 0);
        let records = walk_shard(&data).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].0.n_elem, 3);
        assert_eq!(&data[records[0].1.clone()], b"abc");
        assert_eq!(&data[records[1].1.clone()], b"defgh");
        std::fs::remove_file(&path).ok();
    }

    fn sample_index() -> TensorIndex {
        TensorIndex {
            model: "tiny-llm-7m".into(),
            n_shards: 2,
            entries: vec![
                IndexEntry {
                    name: "embed_tokens".into(),
                    rows: 256,
                    cols: 64,
                    layer: 0,
                    block_type: 0,
                    codec: 0,
                    format: 0,
                    shard: 0,
                    offset: 8,
                    len: 9000,
                    payload_crc: 0xDEAD_BEEF,
                },
                IndexEntry {
                    name: "layers.0.attn.q_proj".into(),
                    rows: 64,
                    cols: 64,
                    layer: 0,
                    block_type: 1,
                    codec: 1,
                    format: 0,
                    shard: 1,
                    offset: 8,
                    len: 4128,
                    payload_crc: 7,
                },
            ],
            layer_extents: vec![LayerExtent {
                layer: 0,
                shard: 1,
                offset: 8,
                len: 4128,
            }],
        }
    }

    #[test]
    fn index_roundtrip() {
        let idx = sample_index();
        let bytes = idx.serialize();
        let back = TensorIndex::deserialize(&bytes).unwrap();
        assert_eq!(back, idx);
        assert_eq!(back.stored_bytes(), 9000 + 4128);
        assert_eq!(back.raw_bytes(), 256 * 64 + 64 * 64);
        let ext = back.layer_extent(0).expect("layer 0 extent recorded");
        assert_eq!((ext.shard, ext.offset, ext.end()), (1, 8, 8 + 4128));
        assert!(back.layer_extent(7).is_none());
    }

    #[test]
    fn v2_index_without_extent_table_still_parses() {
        // hand-build the pre-extent (version 2) serialization and check
        // the v3 reader accepts it with an empty extent table
        let idx = sample_index();
        let v3 = idx.serialize();
        let mut v2 = Vec::new();
        v2.extend_from_slice(&v3[..4]);
        put_u16(&mut v2, V2_VERSION);
        // body minus magic/version, minus extent table, minus CRC
        let extent_bytes = 4 + idx.layer_extents.len() * 24;
        v2.extend_from_slice(&v3[6..v3.len() - 4 - extent_bytes]);
        let crc = crate::util::crc32::crc32(&v2);
        put_u32(&mut v2, crc);
        let back = TensorIndex::deserialize(&v2).unwrap();
        assert_eq!(back.entries, idx.entries);
        assert!(back.layer_extents.is_empty());
    }

    #[test]
    fn record_view_shares_backing_with_source() {
        let payload = b"view-backed payload".to_vec();
        let mut buf = Vec::new();
        let h = RecordHeader {
            codec: 1,
            format: 0,
            n_elem: payload.len() as u64,
            payload_len: payload.len() as u64,
            payload_crc: crate::util::crc32::crc32(&payload),
        };
        h.write_into(&mut buf).unwrap();
        buf.extend_from_slice(&payload);
        let src = ByteView::from_vec(buf);
        let (back, view) = read_record_view(&src).unwrap();
        assert_eq!(back, h);
        assert_eq!(view, payload);
        let outer = src.backing_addr_range();
        let inner = view.addr_range();
        assert!(outer.start <= inner.start && inner.end <= outer.end);
        // truncation through the view reader is still structured
        assert!(matches!(
            read_record_view(&src.slice(0..src.len() - 1)),
            Err(ContainerError::Truncated { .. })
        ));
    }

    #[test]
    fn index_detects_corruption_and_truncation() {
        let idx = sample_index();
        let bytes = idx.serialize();
        // flip a metadata byte => trailer CRC catches it
        let mut bad = bytes.clone();
        bad[20] ^= 0x01;
        assert!(matches!(
            TensorIndex::deserialize(&bad),
            Err(ContainerError::CrcMismatch { .. })
        ));
        // every truncation point is a structured error, never a panic
        for cut in 0..bytes.len() {
            let err = TensorIndex::deserialize(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(
                    err,
                    ContainerError::Truncated { .. } | ContainerError::CrcMismatch { .. }
                ),
                "cut={cut}: {err}"
            );
        }
    }
}
