//! On-disk / wire container for ECF8 blobs.
//!
//! Layout (little-endian):
//!
//! ```text
//! 0    magic "ECF8"            4 bytes
//! 4    version                 u16
//! 6    format                  u8   (0 = E4M3, 1 = E5M2)
//! 7    alphabet                u8
//! 8    n_elem                  u64
//! 16   bytes_per_thread (B)    u32
//! 20   threads_per_block (T)   u32
//! 24   n_blocks                u64
//! 32   encoded_bits            u64
//! 40   encoded_len             u64  (padded length actually stored)
//! 48   packed_len              u64
//! 56   gaps_len                u64
//! 64   payload_crc32           u32
//! 68   reserved                4 bytes
//! 72   code_lengths            `alphabet` bytes
//! ..   outpos                  (n_blocks+1) × u64
//! ..   gaps                    gaps_len bytes
//! ..   packed                  packed_len bytes
//! ..   encoded                 encoded_len bytes
//! ```

use super::{Ecf8Blob, Ecf8Params, Fp8Format};

pub const MAGIC: &[u8; 4] = b"ECF8";
pub const VERSION: u16 = 1;
/// Fixed header size (pre-code_lengths), for size accounting.
pub const HEADER_BYTES: usize = 72;

#[derive(Debug)]
pub enum ContainerError {
    BadMagic,
    BadVersion(u16),
    BadFormat(u8),
    Truncated { need: usize, have: usize },
    CrcMismatch { stored: u32, computed: u32 },
    Inconsistent(&'static str),
}

impl std::fmt::Display for ContainerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ContainerError::BadMagic => write!(f, "bad magic (not an ECF8 container)"),
            ContainerError::BadVersion(v) => write!(f, "unsupported version {v}"),
            ContainerError::BadFormat(b) => write!(f, "unknown format byte {b}"),
            ContainerError::Truncated { need, have } => {
                write!(f, "container truncated: need {need} bytes, have {have}")
            }
            ContainerError::CrcMismatch { stored, computed } => write!(
                f,
                "payload CRC mismatch (stored {stored:#010x}, computed {computed:#010x})"
            ),
            ContainerError::Inconsistent(what) => write!(f, "inconsistent metadata: {what}"),
        }
    }
}

impl std::error::Error for ContainerError {}

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ContainerError> {
        if self.pos + n > self.data.len() {
            return Err(ContainerError::Truncated {
                need: self.pos + n,
                have: self.data.len(),
            });
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16, ContainerError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, ContainerError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, ContainerError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn u8(&mut self) -> Result<u8, ContainerError> {
        Ok(self.take(1)?[0])
    }
}

/// Serialize a blob to container bytes.
pub fn serialize(blob: &Ecf8Blob) -> Vec<u8> {
    let alphabet = blob.format.alphabet_size();
    assert_eq!(blob.code_lengths.len(), alphabet);
    let mut crc = crate::util::crc32::Hasher::new();
    crc.update(&blob.packed);
    crc.update(&blob.encoded);
    crc.update(&blob.gaps);
    let crc = crc.finalize();

    let mut out = Vec::with_capacity(
        HEADER_BYTES
            + alphabet
            + blob.outpos.len() * 8
            + blob.gaps.len()
            + blob.packed.len()
            + blob.encoded.len(),
    );
    out.extend_from_slice(MAGIC);
    put_u16(&mut out, VERSION);
    out.push(blob.format as u8);
    out.push(alphabet as u8);
    put_u64(&mut out, blob.n_elem as u64);
    put_u32(&mut out, blob.params.bytes_per_thread as u32);
    put_u32(&mut out, blob.params.threads_per_block as u32);
    put_u64(&mut out, blob.n_blocks() as u64);
    put_u64(&mut out, blob.encoded_bits);
    put_u64(&mut out, blob.encoded.len() as u64);
    put_u64(&mut out, blob.packed.len() as u64);
    put_u64(&mut out, blob.gaps.len() as u64);
    put_u32(&mut out, crc);
    out.extend_from_slice(&[0u8; 4]); // reserved
    debug_assert_eq!(out.len(), HEADER_BYTES);
    out.extend_from_slice(&blob.code_lengths);
    for &p in &blob.outpos {
        put_u64(&mut out, p);
    }
    out.extend_from_slice(&blob.gaps);
    out.extend_from_slice(&blob.packed);
    out.extend_from_slice(&blob.encoded);
    out
}

/// Deserialize container bytes back into a blob (validates CRC and
/// internal consistency).
pub fn deserialize(data: &[u8]) -> Result<Ecf8Blob, ContainerError> {
    let mut c = Cursor { data, pos: 0 };
    if c.take(4)? != MAGIC {
        return Err(ContainerError::BadMagic);
    }
    let version = c.u16()?;
    if version != VERSION {
        return Err(ContainerError::BadVersion(version));
    }
    let format = Fp8Format::from_u8(c.u8()?).ok_or(ContainerError::BadFormat(255))?;
    let alphabet = c.u8()? as usize;
    if alphabet != format.alphabet_size() {
        return Err(ContainerError::Inconsistent("alphabet size vs format"));
    }
    let n_elem = c.u64()? as usize;
    let bytes_per_thread = c.u32()? as usize;
    let threads_per_block = c.u32()? as usize;
    let n_blocks = c.u64()? as usize;
    let encoded_bits = c.u64()?;
    let encoded_len = c.u64()? as usize;
    let packed_len = c.u64()? as usize;
    let gaps_len = c.u64()? as usize;
    let stored_crc = c.u32()?;
    let _reserved = c.take(4)?;
    let code_lengths = c.take(alphabet)?.to_vec();
    let mut outpos = Vec::with_capacity(n_blocks + 1);
    for _ in 0..=n_blocks {
        outpos.push(c.u64()?);
    }
    let gaps = c.take(gaps_len)?.to_vec();
    let packed = c.take(packed_len)?.to_vec();
    let encoded = c.take(encoded_len)?.to_vec();

    let mut crc = crate::util::crc32::Hasher::new();
    crc.update(&packed);
    crc.update(&encoded);
    crc.update(&gaps);
    let computed = crc.finalize();
    if computed != stored_crc {
        return Err(ContainerError::CrcMismatch {
            stored: stored_crc,
            computed,
        });
    }

    let params = Ecf8Params {
        bytes_per_thread,
        threads_per_block,
    };
    if encoded_len != n_blocks * params.block_bytes() + 8 {
        return Err(ContainerError::Inconsistent("encoded length vs geometry"));
    }
    if packed_len != n_elem.div_ceil(2) {
        return Err(ContainerError::Inconsistent("packed length vs n_elem"));
    }
    if outpos.last().copied() != Some(n_elem as u64) {
        return Err(ContainerError::Inconsistent("outpos tail vs n_elem"));
    }

    Ok(Ecf8Blob {
        format,
        params,
        n_elem,
        code_lengths,
        encoded,
        encoded_bits,
        packed,
        gaps,
        outpos,
    })
}

/// Write a blob to a file.
pub fn write_file(blob: &Ecf8Blob, path: &std::path::Path) -> std::io::Result<()> {
    std::fs::write(path, serialize(blob))
}

/// Read a blob from a file.
pub fn read_file(path: &std::path::Path) -> anyhow::Result<Ecf8Blob> {
    let data = std::fs::read(path)?;
    Ok(deserialize(&data)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::encode::encode;
    use crate::util::prng::Xoshiro256;

    fn sample_blob(n: usize) -> Ecf8Blob {
        let mut rng = Xoshiro256::seed_from_u64(77);
        let data: Vec<u8> = (0..n)
            .map(|_| {
                let x = (crate::util::sampling::normal(&mut rng) * 0.05) as f32;
                crate::fp8::F8E4M3::from_f32(x).to_bits()
            })
            .collect();
        encode(&data, Fp8Format::E4M3, Ecf8Params::default())
    }

    #[test]
    fn serialize_roundtrip() {
        let blob = sample_blob(12_345);
        let bytes = serialize(&blob);
        let back = deserialize(&bytes).unwrap();
        assert_eq!(back.n_elem, blob.n_elem);
        assert_eq!(back.encoded, blob.encoded);
        assert_eq!(back.packed, blob.packed);
        assert_eq!(back.gaps, blob.gaps);
        assert_eq!(back.outpos, blob.outpos);
        assert_eq!(back.code_lengths, blob.code_lengths);
        assert_eq!(back.format, blob.format);
        // and it still decodes losslessly
        let a = crate::codec::decompress_fp8(&blob);
        let b = crate::codec::decompress_fp8(&back);
        assert_eq!(a, b);
    }

    #[test]
    fn detects_corruption() {
        let blob = sample_blob(5000);
        let mut bytes = serialize(&blob);
        let n = bytes.len();
        bytes[n - 100] ^= 0xFF; // flip payload bits
        assert!(matches!(
            deserialize(&bytes),
            Err(ContainerError::CrcMismatch { .. })
        ));
    }

    #[test]
    fn detects_truncation() {
        let blob = sample_blob(5000);
        let bytes = serialize(&blob);
        assert!(matches!(
            deserialize(&bytes[..bytes.len() - 9]),
            Err(ContainerError::Truncated { .. })
        ));
        assert!(matches!(
            deserialize(&bytes[..30]),
            Err(ContainerError::Truncated { .. })
        ));
    }

    #[test]
    fn detects_bad_magic_and_version() {
        let blob = sample_blob(100);
        let mut bytes = serialize(&blob);
        bytes[0] = b'X';
        assert!(matches!(deserialize(&bytes), Err(ContainerError::BadMagic)));
        let mut bytes = serialize(&blob);
        bytes[4] = 99;
        assert!(matches!(
            deserialize(&bytes),
            Err(ContainerError::BadVersion(_))
        ));
    }

    #[test]
    fn file_roundtrip() {
        let blob = sample_blob(2000);
        let dir = std::env::temp_dir().join("ecf8_container_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ecf8");
        write_file(&blob, &path).unwrap();
        let back = read_file(&path).unwrap();
        assert_eq!(
            crate::codec::decompress_fp8(&back),
            crate::codec::decompress_fp8(&blob)
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_overhead_is_small() {
        let blob = sample_blob(1_000_000);
        let bytes = serialize(&blob);
        let payload = blob.encoded.len() + blob.packed.len() + blob.gaps.len();
        // metadata overhead < 2% for MB-scale tensors
        assert!((bytes.len() - payload) as f64 / (bytes.len() as f64) < 0.02);
    }
}
