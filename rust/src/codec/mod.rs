//! The ECF8 lossless compression format (§3).
//!
//! An FP8 tensor is split into two streams:
//!
//! * the 4-bit **exponent fields**, Huffman-coded (§3.1) into a bitstream
//!   with per-thread *gap* metadata and per-block *output positions* so
//!   thread blocks decode autonomously (§3.1 "synchronization metadata");
//! * the 4-bit **sign/mantissa nibbles**, packed two per byte, stored raw
//!   (they are near-incompressible: mantissas of trained weights are
//!   close to uniform).
//!
//! [`encode`] builds the streams; [`decode`] reconstructs the original
//! bytes, bit-exactly, via the block-parallel scheme of Algorithm 1.

pub mod codecs;
pub mod container;
pub mod decode;
pub mod encode;
pub mod simd;

use crate::huffman::canonical::CanonicalCode;
use crate::huffman::lut::DecodeLut;
use crate::util::mmap::ByteView;

/// Which FP8 flavour a blob holds. Determines the exponent alphabet and
/// the sign/mantissa packing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fp8Format {
    /// 4-bit exponent, 1+3-bit sign/mantissa nibble (the paper's format).
    E4M3 = 0,
    /// 5-bit exponent, 1+2-bit sign/mantissa rest (stored in a nibble).
    E5M2 = 1,
}

impl Fp8Format {
    pub fn alphabet_size(self) -> usize {
        match self {
            Fp8Format::E4M3 => 16,
            Fp8Format::E5M2 => 32,
        }
    }

    /// Split an FP8 byte into (exponent symbol, rest nibble).
    #[inline(always)]
    pub fn split(self, byte: u8) -> (u8, u8) {
        match self {
            Fp8Format::E4M3 => ((byte >> 3) & 0x0F, ((byte >> 4) & 0x08) | (byte & 0x07)),
            Fp8Format::E5M2 => ((byte >> 2) & 0x1F, ((byte >> 5) & 0x04) | (byte & 0x03)),
        }
    }

    /// Reassemble an FP8 byte from (exponent symbol, rest nibble) —
    /// Algorithm 1 line 24 generalised.
    #[inline(always)]
    pub fn assemble(self, sym: u8, rest: u8) -> u8 {
        match self {
            Fp8Format::E4M3 => ((rest & 0x08) << 4) | (sym << 3) | (rest & 0x07),
            Fp8Format::E5M2 => ((rest & 0x04) << 5) | (sym << 2) | (rest & 0x03),
        }
    }

    pub fn from_u8(v: u8) -> Option<Self> {
        match v {
            0 => Some(Fp8Format::E4M3),
            1 => Some(Fp8Format::E5M2),
            _ => None,
        }
    }
}

/// Block-geometry parameters of the parallel decoder (paper defaults:
/// B = 8 bytes per thread, T = 256 threads per block).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ecf8Params {
    /// B — bytes of the encoded stream owned by one (simulated) thread.
    pub bytes_per_thread: usize,
    /// T — threads per block.
    pub threads_per_block: usize,
}

impl Default for Ecf8Params {
    fn default() -> Self {
        Self {
            bytes_per_thread: 8,
            threads_per_block: 256,
        }
    }
}

impl Ecf8Params {
    pub fn block_bytes(&self) -> usize {
        self.bytes_per_thread * self.threads_per_block
    }
}

/// A compressed tensor: the ECF8 streams plus their metadata.
#[derive(Debug, Clone)]
pub struct Ecf8Blob {
    pub format: Fp8Format,
    pub params: Ecf8Params,
    /// number of original FP8 elements
    pub n_elem: usize,
    /// canonical Huffman code lengths per exponent symbol (the code book
    /// is fully determined by these)
    pub code_lengths: Vec<u8>,
    /// Huffman bitstream, zero-padded to `n_blocks·T·B + 8` bytes. The
    /// streams are [`ByteView`]s so a blob parsed from a mapped shard
    /// decodes straight out of the page cache (encoder-built blobs carry
    /// owned buffers behind the same type).
    pub encoded: ByteView,
    /// true bit length of the stream (pre-padding)
    pub encoded_bits: u64,
    /// packed rest nibbles, two per byte, first element in the high nibble
    pub packed: ByteView,
    /// packed 4-bit per-thread gaps, even thread in the high nibble
    pub gaps: ByteView,
    /// per-block cumulative output element counts, length `n_blocks + 1`
    pub outpos: Vec<u64>,
}

impl Ecf8Blob {
    pub fn n_blocks(&self) -> usize {
        self.outpos.len() - 1
    }

    pub fn n_threads(&self) -> usize {
        self.n_blocks() * self.params.threads_per_block
    }

    /// Compressed payload size in bytes (streams + metadata), the number
    /// the paper's Table 1 "Memory (GB)" columns report.
    pub fn compressed_bytes(&self) -> usize {
        // count the unpadded stream plus all metadata the decoder needs
        let stream = (self.encoded_bits as usize).div_ceil(8);
        stream
            + self.packed.len()
            + self.gaps.len()
            + self.outpos.len() * 8
            + self.code_lengths.len()
            + container::HEADER_BYTES
    }

    pub fn compression_ratio(&self) -> f64 {
        self.n_elem as f64 / self.compressed_bytes() as f64
    }

    /// Fraction of memory saved vs. raw FP8 (Table 1 "Memory ↓ (%)").
    pub fn memory_saving(&self) -> f64 {
        1.0 - self.compressed_bytes() as f64 / self.n_elem as f64
    }

    /// Rebuild the canonical code book from the stored lengths.
    pub fn code(&self) -> CanonicalCode {
        let lengths: Vec<u32> = self.code_lengths.iter().map(|&l| l as u32).collect();
        CanonicalCode::from_lengths(&lengths).expect("stored lengths are valid")
    }

    /// Rebuild the decode LUT.
    pub fn lut(&self) -> DecodeLut {
        DecodeLut::build(&self.code())
    }
}

pub use codecs::{compress_auto, select_codec, Codec, CodecId, CompressedTensor};
pub use decode::{DecodePath, DecodeTableCache, DecodeTables};
pub use encode::{encode_parallel, encode_with_code_parallel};

/// Compress FP8 bytes (default params, E4M3). See [`encode::encode`].
pub fn compress_fp8(data: &[u8]) -> Ecf8Blob {
    encode::encode(data, Fp8Format::E4M3, Ecf8Params::default())
}

/// Parallel [`compress_fp8`] — byte-identical output, chunked two-pass
/// encode on `pool`. See [`encode::encode_with_code_parallel`].
pub fn compress_fp8_parallel(data: &[u8], pool: &crate::util::threadpool::ThreadPool) -> Ecf8Blob {
    encode::encode_parallel(data, Fp8Format::E4M3, Ecf8Params::default(), pool)
}

/// Decompress into a fresh buffer. See [`decode::decode_into`].
pub fn decompress_fp8(blob: &Ecf8Blob) -> Vec<u8> {
    let mut out = vec![0u8; blob.n_elem];
    decode::decode_into(blob, &mut out, None);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_split_assemble_roundtrip() {
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            for b in 0..=255u8 {
                let (sym, rest) = fmt.split(b);
                assert!(sym < fmt.alphabet_size() as u8);
                assert!(rest < 16);
                assert_eq!(fmt.assemble(sym, rest), b, "fmt={fmt:?} byte={b:#04x}");
            }
        }
    }

    #[test]
    fn format_codes() {
        assert_eq!(Fp8Format::from_u8(0), Some(Fp8Format::E4M3));
        assert_eq!(Fp8Format::from_u8(1), Some(Fp8Format::E5M2));
        assert_eq!(Fp8Format::from_u8(9), None);
    }

    #[test]
    fn default_params_match_paper() {
        let p = Ecf8Params::default();
        assert_eq!(p.bytes_per_thread, 8);
        assert_eq!(p.threads_per_block, 256);
        assert_eq!(p.block_bytes(), 2048);
    }
}
