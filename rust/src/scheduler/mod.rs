//! Continuous-batching scheduler with a paged, ECF8-compressible
//! KV-cache manager — the ROADMAP's "KV-cache-aware continuous batching"
//! rung.
//!
//! The coordinator's serving loop so far is *batch-level*: form a
//! rectangle of requests, execute it to completion, repeat
//! ([`crate::coordinator::Server`] and the pipelined variant overlap the
//! stages but keep the rectangle). This module replaces that with
//! *iteration-level* scheduling in the vLLM/Orca shape, specialised to
//! this repo's compression story:
//!
//! * [`kv_cache`] — [`kv_cache::KvCacheManager`]: a paged,
//!   *refcounted* block pool (fixed-size token blocks, per-sequence
//!   copy-on-write block tables). Preempted sequences do not spill
//!   raw bytes: their private KV blocks are **evicted through the
//!   [`crate::codec::codecs`] registry** — `ecf8-huffman` or `raw-fp8`
//!   chosen per block by the paper's §3.2 entropy probe — and restored
//!   losslessly on resume; *shared* blocks stay pinned under the trie.
//!   Heilper & Singer (2025) show K/V caches concentrate exponents
//!   like weights do, so the same machinery applies.
//! * [`prefix`] — the radix prefix index behind multi-tenant prompt
//!   reuse: admission links already-resident prompt blocks
//!   (refcount++, prefill skipped), cold shared prefixes tier down to
//!   a bounded codec-compressed pool instead of being freed
//!   (hot → compressed → dropped, LRU by last hit), and a hit on a
//!   compressed prefix restores bit-identically.
//! * [`workload`] — seeded multi-tenant request generators (N shared
//!   system prompts + private user suffixes) shared by `kv-sim
//!   --prefix`, `bench_prefix`, and the invariant tests.
//! * [`policy`] — [`policy::ContinuousScheduler`]: iteration-level
//!   admission (new sequences join running iterations the moment blocks
//!   are free), preemption under block pressure (lowest priority first,
//!   newest first within a priority), FIFO resume; plus the static
//!   batch-to-completion baseline ([`policy::run_static`]) and a
//!   threaded [`policy::ContinuousServer`] mirroring
//!   [`crate::coordinator::PipelinedServer`]'s submit/collect/shutdown
//!   surface.
//! * [`pressure`] — the overload governor: low/high/critical
//!   watermarks over the block pool drive a deterministic degradation
//!   ladder (compress idle trie blocks → pause admission under the
//!   reactive preemption path → shed structurally), per-tenant
//!   token-bucket rates and KV-block quotas, weighted
//!   deficit-round-robin admission with priority aging, and the
//!   hysteretic Normal → Brownout → Shed [`pressure::ModeMachine`].
//! * [`iteration`] — [`iteration::IterationEngine`]: the ragged
//!   per-iteration execution seam (per-sequence lengths, no padding
//!   waste), extending [`crate::coordinator::BatchEngine`]. Implemented
//!   by the deterministic [`iteration::SyntheticIterationEngine`]
//!   (every scheduling decision testable and benchable without
//!   artifacts) and by [`crate::runtime::executor::LlmExecutor`]
//!   (fixed-shape AOT rectangles re-scoring a sliding window; the KV
//!   manager supplies the paging/eviction memory mechanism).
//!
//! Everything the scheduler decides — admission order, preemption
//! victim, block accounting, evict/restore bit-identity — is a pure
//! function of its inputs plus the injected [`Clock`], so the sim tests
//! and `ecf8 kv-sim` replay identical schedules from a seed.

pub mod iteration;
pub mod kv_cache;
pub mod policy;
pub mod prefix;
pub mod pressure;
pub mod workload;

pub use iteration::{IterationBatch, IterationEngine, SeqSlot, SyntheticIterationEngine};
pub use kv_cache::{BlockPlan, KvCacheConfig, KvCacheManager, KvError, KvStats};
pub use policy::{
    run_static, ContinuousReport, ContinuousScheduler, ContinuousServer, FinishReason, GenRequest,
    GenResponse, SchedConfig, StepReport,
};
pub use prefix::{PrefixCacheConfig, PrefixStats, TierCensus};
pub use pressure::{
    BrownoutPolicy, ModeMachine, PressureConfig, PressureGovernor, PressureLevel, PressureMetrics,
    ServeMode, TenantCounters, TenantId, TenantPolicy, TokenBucket, Watermarks,
};
pub use workload::{overload_requests, shared_prefix_requests, SharedPrefixWorkload};

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// The scheduler's time source. One trait for every clock consumer —
/// the continuous scheduler's TTFT/TPOT stamps and the
/// [`crate::coordinator::DynamicBatcher`]'s linger policy share it, so
/// sim tests drive both from a single [`SimClock`].
pub trait Clock: Send + Sync {
    fn now(&self) -> Instant;
}

/// The real wall clock (production default).
#[derive(Debug, Default, Clone, Copy)]
pub struct SystemClock;

impl Clock for SystemClock {
    fn now(&self) -> Instant {
        Instant::now()
    }
}

/// A deterministic, manually advanced clock for synchronous sim tests:
/// a settable offset over a fixed origin. Not for the *threaded*
/// coordinators — their condvar waits sleep in real time.
#[derive(Debug)]
pub struct SimClock {
    origin: Instant,
    offset: Mutex<Duration>,
}

impl SimClock {
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            origin: Instant::now(),
            offset: Mutex::new(Duration::ZERO),
        })
    }

    /// Move time forward by `d` (monotone by construction).
    pub fn advance(&self, d: Duration) {
        *self.offset.lock().unwrap() += d;
    }
}

impl Clock for SimClock {
    fn now(&self) -> Instant {
        self.origin + *self.offset.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances_deterministically() {
        let clock = SimClock::new();
        let t0 = clock.now();
        assert_eq!(clock.now(), t0, "no implicit progress");
        clock.advance(Duration::from_millis(5));
        assert_eq!(clock.now(), t0 + Duration::from_millis(5));
        clock.advance(Duration::from_millis(7));
        assert_eq!(clock.now(), t0 + Duration::from_millis(12));
    }

    #[test]
    fn system_clock_is_monotone() {
        let c = SystemClock;
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }
}
