//! Iteration-level scheduling policy: continuous admission, preemption
//! under block pressure, FIFO resume — plus the static
//! batch-to-completion baseline it is measured against.
//!
//! ## The policy, exactly
//!
//! Each [`ContinuousScheduler::step`] is one engine iteration:
//!
//! 1. **Resume** preempted sequences, oldest preemption first, while
//!    the pool can hold each one's restored KV plus one token of
//!    headroom. Head-of-line: if the front cannot fit, nothing behind
//!    it resumes (no starvation by queue-jumping).
//! 2. **Admit** waiting requests — highest priority first, submission
//!    order within a priority — while blocks cover `prompt + 1` tokens
//!    and the live width is under `max_running`. Preempted sequences
//!    have strict precedence: while any wait to resume, nothing new is
//!    admitted.
//! 3. **Grow** every running sequence by one token of KV capacity. A
//!    sequence that cannot grow triggers preemption: the victim is the
//!    lowest-priority running sequence, newest admission first within a
//!    priority, evicted through the codec registry
//!    ([`super::kv_cache::KvCacheManager::evict`]). A sequence may
//!    victimise itself (then it skips this iteration).
//! 4. **Run** one ragged iteration over the survivors, greedy-pick each
//!    next token ([`super::iteration::argmax`]), write its KV, and
//!    retire sequences that reached their budget (blocks freed the same
//!    step).
//!
//! Every choice is deterministic given the submission order, so the
//! sim tests replay identical schedules — and because generated tokens
//! are a pure per-sequence function (see [`super::iteration`]), the
//! continuous schedule must produce *identical responses* to the static
//! baseline, preemptions and all. That identity is the subsystem's
//! core test.

use super::iteration::{argmax, IterationBatch, IterationEngine, SeqSlot};
use super::kv_cache::{KvCacheConfig, KvCacheManager, KvError, KvStats};
use super::pressure::{PressureGovernor, PressureLevel, PressureMetrics, ServeMode, TenantId};
use super::Clock;
use crate::coordinator::metrics::SchedulerMetrics;
use crate::coordinator::supervisor::{Heartbeat, StageHealth};
use crate::telemetry::recorder::{FlightEvent, FlightRecorder, ShedKind};
use crate::telemetry::span::{Phase, TraceContext, TraceSummary, Tracer};
use crate::util::channel::{self, RecvTimeoutError};
use crate::util::threadpool::ThreadPool;
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A generation request: prompt in, `max_new_tokens` greedy tokens out.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// higher admits (and survives preemption) first
    pub priority: u8,
    /// who this request bills to — quota/rate/fairness bucket under the
    /// overload governor (0 = the default tenant, pre-multi-tenancy)
    pub tenant: TenantId,
    pub arrived: Instant,
    /// optional service deadline: a request still *waiting* at this
    /// instant is shed with a structured [`FinishReason::Expired`]
    /// response instead of being admitted (`>=` — exactly at the
    /// deadline counts as expired). A queueing SLO by default:
    /// sequences already running are killed by it only under the
    /// governor's opt-in `cancel_past_deadline`, which cuts them off
    /// mid-generation with [`FinishReason::Cancelled`].
    pub deadline: Option<Instant>,
    /// span handle, assigned at [`ContinuousScheduler::submit`] when a
    /// tracer is attached; `None` otherwise (or when the trace arena
    /// was full)
    pub trace: Option<TraceContext>,
}

impl GenRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Self::at(id, prompt, max_new_tokens, Instant::now())
    }

    /// Construction with an explicit arrival stamp (sim clocks, and the
    /// open-loop benches' pre-planned arrival schedules).
    pub fn at(id: u64, prompt: Vec<i32>, max_new_tokens: usize, arrived: Instant) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(max_new_tokens > 0, "zero generation budget");
        Self {
            id,
            prompt,
            max_new_tokens,
            priority: 0,
            tenant: 0,
            arrived,
            deadline: None,
            trace: None,
        }
    }

    pub fn with_priority(mut self, priority: u8) -> Self {
        self.priority = priority;
        self
    }

    pub fn with_tenant(mut self, tenant: TenantId) -> Self {
        self.tenant = tenant;
        self
    }

    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// How a generation ended — completion is the quiet case; expiry is
/// structured so callers can tell "served" from "shed at the deadline"
/// without sniffing for empty token vectors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FinishReason {
    /// generated its full `max_new_tokens` budget
    #[default]
    Completed,
    /// shed while waiting: the deadline passed before admission
    Expired,
    /// shed while waiting by the overload governor: the queue bound or
    /// Shed mode rejected it structurally (never admitted, no KV)
    Rejected,
    /// cancelled mid-generation: the deadline passed while running and
    /// the governor's opt-in `cancel_past_deadline` freed its KV
    /// through the normal release path (partial tokens returned)
    Cancelled,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    /// the generated tokens (prompt excluded; empty when expired)
    pub tokens: Vec<i32>,
    /// arrival → first generated token (0 when expired — never ran)
    pub ttft_s: f64,
    /// arrival → last generated token (arrival → shed when expired)
    pub latency_s: f64,
    /// times this sequence was evicted and restored
    pub preemptions: u32,
    pub finish: FinishReason,
    /// per-phase latency breakdown, when the scheduler traced this
    /// request (Σ `trace.phase_ns` == `trace.total_ns` by construction)
    pub trace: Option<TraceSummary>,
}

impl GenResponse {
    /// True for a normally completed generation.
    pub fn is_complete(&self) -> bool {
        self.finish == FinishReason::Completed
    }

    /// The structured shed-at-deadline response (no tokens generated).
    pub fn expired(req: &GenRequest, now: Instant) -> Self {
        Self {
            id: req.id,
            tokens: Vec::new(),
            ttft_s: 0.0,
            latency_s: now.saturating_duration_since(req.arrived).as_secs_f64(),
            preemptions: 0,
            finish: FinishReason::Expired,
            trace: None,
        }
    }

    /// The structured governor rejection (shed while waiting — never
    /// admitted, never touched the KV pool).
    pub fn rejected(req: &GenRequest, now: Instant) -> Self {
        Self {
            id: req.id,
            tokens: Vec::new(),
            ttft_s: 0.0,
            latency_s: now.saturating_duration_since(req.arrived).as_secs_f64(),
            preemptions: 0,
            finish: FinishReason::Rejected,
            trace: None,
        }
    }
}

/// Continuous-scheduler knobs.
#[derive(Debug, Clone, Copy)]
pub struct SchedConfig {
    /// cap on live iteration slots (the ragged batch width)
    pub max_running: usize,
}

/// What one [`ContinuousScheduler::step`] did.
#[derive(Debug, Default)]
pub struct StepReport {
    pub responses: Vec<GenResponse>,
    /// live slots executed this iteration
    pub ran: usize,
    pub admitted: usize,
    pub resumed: usize,
    pub preempted: usize,
}

impl StepReport {
    /// True when the step neither ran, admitted, resumed, nor finished
    /// anything — with work still queued this means the head sequence
    /// can never fit the pool (a configuration error, surfaced).
    pub fn no_progress(&self) -> bool {
        self.ran == 0 && self.admitted == 0 && self.resumed == 0 && self.responses.is_empty()
    }
}

struct ActiveSeq {
    req: GenRequest,
    /// prompt + generated, newest last
    tokens: Vec<i32>,
    /// stable admission tiebreak (newest = largest)
    admit_seq: u64,
    /// worst-case blocks charged against the tenant quota at admission
    /// (0 when no governor is attached); held across preemption,
    /// released with the sequence
    reserved_blocks: usize,
    /// KV positions whose compute has been charged to the engine:
    /// prefix-matched positions at admission (their prefill was
    /// skipped), then the scored length after every iteration. The
    /// difference to `tokens.len()` is the slot's `new_tokens`.
    scored_upto: usize,
    first_token_at: Option<Instant>,
    last_token_at: Instant,
    preemptions: u32,
}

impl ActiveSeq {
    fn generated(&self) -> usize {
        self.tokens.len() - self.req.prompt.len()
    }

    fn finished(&self) -> bool {
        self.generated() >= self.req.max_new_tokens
    }
}

/// The iteration-level scheduler: owns the paged KV cache and the
/// waiting / running / preempted sequence sets.
pub struct ContinuousScheduler {
    cfg: SchedConfig,
    kv: KvCacheManager,
    clock: Arc<dyn Clock>,
    pool: Option<Arc<ThreadPool>>,
    /// (submission counter, request) — selection is priority-major,
    /// submission-order-minor
    waiting: Vec<(u64, GenRequest)>,
    running: Vec<ActiveSeq>,
    preempted: VecDeque<ActiveSeq>,
    pub metrics: SchedulerMetrics,
    submit_counter: u64,
    admit_counter: u64,
    /// the overload governor — `None` keeps every pre-governor code
    /// path byte-identical
    governor: Option<PressureGovernor>,
    /// the span tracer — `None` keeps the untraced hot path untouched
    tracer: Option<Tracer>,
    /// the shared flight recorder, also handed to the governor
    recorder: Option<Arc<FlightRecorder>>,
}

impl ContinuousScheduler {
    pub fn new(cfg: SchedConfig, kv_cfg: KvCacheConfig, clock: Arc<dyn Clock>) -> Self {
        assert!(cfg.max_running > 0, "zero-width scheduler");
        Self {
            cfg,
            kv: KvCacheManager::new(kv_cfg),
            clock,
            pool: None,
            waiting: Vec::new(),
            running: Vec::new(),
            preempted: VecDeque::new(),
            metrics: SchedulerMetrics::default(),
            submit_counter: 0,
            admit_counter: 0,
            governor: None,
            tracer: None,
            recorder: None,
        }
    }

    /// Attach a thread pool for parallel KV restores.
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Attach the overload governor: watermark cascade, per-tenant
    /// quotas, DRR admission, brownout modes. Without it the scheduler
    /// behaves exactly as before.
    pub fn with_governor(mut self, mut governor: PressureGovernor) -> Self {
        if let Some(rc) = &self.recorder {
            governor.set_recorder(rc.clone());
        }
        self.governor = Some(governor);
        self
    }

    /// Attach the span tracer: every submitted request gets a span
    /// moved through queued/prefill/decode/preempted/kv_evict/
    /// kv_restore at the exact state-change sites, with codec bytes
    /// and time attributed per request. Build the tracer on the same
    /// clock as the scheduler.
    pub fn with_tracer(mut self, tracer: Tracer) -> Self {
        self.tracer = Some(tracer);
        self
    }

    pub fn tracer(&self) -> Option<&Tracer> {
        self.tracer.as_ref()
    }

    /// Attach the shared flight recorder. Preemptions, reclaim sweeps,
    /// quota rejections, and sheds land in its ring; the governor (if
    /// attached, in either order) records its mode transitions and
    /// arms a postmortem on Shed entry, which [`Self::step`] flushes
    /// at its end-of-step safe point.
    pub fn with_recorder(mut self, recorder: Arc<FlightRecorder>) -> Self {
        if let Some(g) = self.governor.as_mut() {
            g.set_recorder(recorder.clone());
        }
        self.recorder = Some(recorder);
        self
    }

    pub fn recorder(&self) -> Option<&Arc<FlightRecorder>> {
        self.recorder.as_ref()
    }

    pub fn governor(&self) -> Option<&PressureGovernor> {
        self.governor.as_ref()
    }

    pub fn governor_mut(&mut self) -> Option<&mut PressureGovernor> {
        self.governor.as_mut()
    }

    pub fn submit(&mut self, mut req: GenRequest) {
        if let Some(g) = self.governor.as_mut() {
            g.metrics.tenant(req.tenant).submitted += 1;
        }
        if let Some(t) = self.tracer.as_mut() {
            // backdated to the arrival stamp so pre-submit queueing
            // (open-loop arrival schedules) lands in the queued phase
            req.trace = t.open_at(req.id, req.arrived);
        }
        self.waiting.push((self.submit_counter, req));
        self.submit_counter += 1;
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty() || !self.preempted.is_empty()
    }

    /// Requests queued but not yet admitted — under a governor this is
    /// bounded by `PressureConfig::max_waiting` after every step.
    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn kv(&self) -> &KvCacheManager {
        &self.kv
    }

    /// Live sequence ids in iteration order (test observability).
    pub fn running_ids(&self) -> Vec<u64> {
        self.running.iter().map(|s| s.req.id).collect()
    }

    /// Preempted sequence ids, oldest preemption first.
    pub fn preempted_ids(&self) -> Vec<u64> {
        self.preempted.iter().map(|s| s.req.id).collect()
    }

    /// Index of the next waiting request to admit: highest priority,
    /// then earliest submission. `None` when the queue is empty.
    fn pick_waiting(&self) -> Option<usize> {
        self.waiting
            .iter()
            .enumerate()
            .max_by_key(|(_, (sub, r))| (r.priority, std::cmp::Reverse(*sub)))
            .map(|(i, _)| i)
    }

    /// Index of the preemption victim among `running`: lowest priority,
    /// newest admission within a priority.
    fn pick_victim(&self) -> Option<usize> {
        self.running
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| (s.req.priority, std::cmp::Reverse(s.admit_seq)))
            .map(|(i, _)| i)
    }

    // -- telemetry seams ------------------------------------------------
    //
    // Static (field-splitting) helpers: the governor paths hold a
    // long-lived `&mut` on `self.governor`, so everything touching the
    // tracer/recorder takes the disjoint fields explicitly.

    /// Move a request's span into `phase` (no-op untraced).
    fn trace_enter(tracer: &mut Option<Tracer>, ctx: Option<TraceContext>, phase: Phase) {
        if let (Some(t), Some(ctx)) = (tracer.as_mut(), ctx) {
            t.transition(ctx, phase);
        }
    }

    /// Close a request's span, returning the breakdown for the
    /// response (no-op untraced).
    fn trace_close(tracer: &mut Option<Tracer>, ctx: Option<TraceContext>) -> Option<TraceSummary> {
        match (tracer.as_mut(), ctx) {
            (Some(t), Some(ctx)) => t.close(ctx),
            _ => None,
        }
    }

    /// Clock stamp before a KV codec call (0 untraced — unused then).
    fn trace_now_ns(tracer: &Option<Tracer>) -> u64 {
        tracer.as_ref().map(|t| t.now_ns()).unwrap_or(0)
    }

    /// (raw, stored) restore-direction ledger snapshot.
    fn restore_ledger(kv: &KvCacheManager) -> (u64, u64) {
        let s = kv.stats();
        (s.restored_raw_bytes, s.restored_stored_bytes)
    }

    /// (raw, stored, blocks incl. shared-retained) evict-direction
    /// ledger snapshot.
    fn evict_ledger(kv: &KvCacheManager) -> (u64, u64, u64) {
        let s = kv.stats();
        (
            s.evicted_raw_bytes,
            s.evicted_stored_bytes,
            s.blocks_evicted + s.shared_blocks_retained,
        )
    }

    /// Attribute the codec work a restore-direction KV call just did
    /// (ledger delta since `pre`) to the request's span.
    fn attribute_restore(
        tracer: &mut Option<Tracer>,
        kv: &KvCacheManager,
        ctx: Option<TraceContext>,
        t0_ns: u64,
        pre: (u64, u64),
    ) {
        let (Some(t), Some(ctx)) = (tracer.as_mut(), ctx) else {
            return;
        };
        let (raw1, stored1) = Self::restore_ledger(kv);
        if raw1 > pre.0 {
            let ns = t.now_ns().saturating_sub(t0_ns);
            t.codec_restore(ctx, ns, raw1 - pre.0, stored1 - pre.1);
        }
    }

    /// Attribute the codec work an evict just did to the span.
    fn attribute_evict(
        tracer: &mut Option<Tracer>,
        kv: &KvCacheManager,
        ctx: Option<TraceContext>,
        t0_ns: u64,
        pre: (u64, u64, u64),
    ) {
        let (Some(t), Some(ctx)) = (tracer.as_mut(), ctx) else {
            return;
        };
        let s = kv.stats();
        if s.evicted_raw_bytes > pre.0 {
            let ns = t.now_ns().saturating_sub(t0_ns);
            t.codec_evict(
                ctx,
                ns,
                s.evicted_raw_bytes - pre.0,
                s.evicted_stored_bytes - pre.1,
            );
        }
    }

    fn evict_running(&mut self, idx: usize) -> Result<()> {
        let mut victim = self.running.remove(idx);
        let ctx = victim.req.trace;
        Self::trace_enter(&mut self.tracer, ctx, Phase::KvEvict);
        let t0 = Self::trace_now_ns(&self.tracer);
        let pre = Self::evict_ledger(&self.kv);
        self.kv.evict(victim.req.id)?;
        Self::attribute_evict(&mut self.tracer, &self.kv, ctx, t0, pre);
        Self::trace_enter(&mut self.tracer, ctx, Phase::Preempted);
        if let Some(rc) = &self.recorder {
            let blocks = (Self::evict_ledger(&self.kv).2 - pre.2) as usize;
            rc.record(FlightEvent::Preemption {
                req: victim.req.id,
                blocks,
            });
        }
        victim.preemptions += 1;
        self.metrics.preemptions += 1;
        self.preempted.push_back(victim);
        Ok(())
    }

    /// Structured governor rejection of a queued request (never
    /// admitted, never touched the KV pool).
    fn shed_waiter(
        g: &mut PressureGovernor,
        metrics: &mut SchedulerMetrics,
        report: &mut StepReport,
        tracer: &mut Option<Tracer>,
        recorder: &Option<Arc<FlightRecorder>>,
        kind: ShedKind,
        req: &GenRequest,
        now: Instant,
    ) {
        g.metrics.shed_waiting += 1;
        g.metrics.tenant(req.tenant).shed += 1;
        metrics.rejected += 1;
        if let Some(rc) = recorder {
            rc.record(FlightEvent::Shed { req: req.id, kind });
        }
        let trace = Self::trace_close(tracer, req.trace);
        let mut resp = GenResponse::rejected(req, now);
        resp.trace = trace;
        report.responses.push(resp);
    }

    /// Mid-generation cancellation bookkeeping: the sequence's KV was
    /// already released; hand back its partial tokens structurally.
    fn finish_cancel(
        g: &mut PressureGovernor,
        metrics: &mut SchedulerMetrics,
        report: &mut StepReport,
        tracer: &mut Option<Tracer>,
        recorder: &Option<Arc<FlightRecorder>>,
        seq: ActiveSeq,
        now: Instant,
    ) {
        g.release_reservation(seq.req.tenant, seq.reserved_blocks, now);
        g.metrics.cancelled += 1;
        g.metrics.tenant(seq.req.tenant).cancelled += 1;
        metrics.cancelled += 1;
        if let Some(rc) = recorder {
            rc.record(FlightEvent::Shed {
                req: seq.req.id,
                kind: ShedKind::Cancelled,
            });
        }
        report.responses.push(GenResponse {
            id: seq.req.id,
            tokens: seq.tokens[seq.req.prompt.len()..].to_vec(),
            ttft_s: seq
                .first_token_at
                .map(|t| t.saturating_duration_since(seq.req.arrived).as_secs_f64())
                .unwrap_or(0.0),
            latency_s: now.saturating_duration_since(seq.req.arrived).as_secs_f64(),
            preemptions: seq.preemptions,
            finish: FinishReason::Cancelled,
            trace: Self::trace_close(tracer, seq.req.trace),
        });
    }

    /// Governor pre-pass (phase 0b): observe the pool, run the
    /// proactive cascade rungs. Order: classify pressure → High-level
    /// idle reclaim through the codec registry → opt-in past-deadline
    /// cancellation → structural queue bounding (Shed mode rejects
    /// everything queued; otherwise the waiting queue is capped at
    /// `max_waiting`, shedding the lowest-effective-priority tail).
    fn govern(&mut self, now: Instant, report: &mut StepReport) -> Result<()> {
        let total = self.kv.config().n_blocks;
        let used = self.kv.blocks_in_use();
        let g = self.governor.as_mut().expect("governor attached");
        let (level, mode) = g.observe(used, total, now);

        // rung 1 — High watermark: compress idle prefix-trie blocks
        // back to the free list (the same §3.2-probed codec path
        // `take_free` uses reactively), then re-classify on the freed
        // pool so admission sees the post-reclaim level
        if level >= PressureLevel::High {
            let target = g.reclaim_target(total);
            let freed = self.kv.reclaim_idle(target);
            g.note_reclaim(freed);
            g.reclassify(self.kv.blocks_in_use(), total);
            if let Some(rc) = &self.recorder {
                rc.record(FlightEvent::ReclaimSweep { target, freed });
            }
        }

        // opt-in mid-generation deadline cancellation (`>=`, like every
        // deadline in this crate). KV is freed through the normal
        // release path — which handles evicted sequences too, so
        // preempted runners cancel without being restored first.
        if g.config().cancel_past_deadline {
            let mut i = 0;
            while i < self.running.len() {
                match self.running[i].req.deadline {
                    Some(d) if now >= d => {
                        let seq = self.running.remove(i);
                        self.kv.release(seq.req.id)?;
                        Self::finish_cancel(
                            g,
                            &mut self.metrics,
                            report,
                            &mut self.tracer,
                            &self.recorder,
                            seq,
                            now,
                        );
                    }
                    _ => i += 1,
                }
            }
            let mut i = 0;
            while i < self.preempted.len() {
                match self.preempted[i].req.deadline {
                    Some(d) if now >= d => {
                        let seq = self.preempted.remove(i).expect("index checked");
                        self.kv.release(seq.req.id)?;
                        Self::finish_cancel(
                            g,
                            &mut self.metrics,
                            report,
                            &mut self.tracer,
                            &self.recorder,
                            seq,
                            now,
                        );
                    }
                    _ => i += 1,
                }
            }
        }

        // rung 3 — structural shedding keeps the queue bounded
        if mode == ServeMode::Shed {
            for (_, req) in std::mem::take(&mut self.waiting) {
                Self::shed_waiter(
                    g,
                    &mut self.metrics,
                    report,
                    &mut self.tracer,
                    &self.recorder,
                    ShedKind::ShedMode,
                    &req,
                    now,
                );
            }
        } else {
            let max_waiting = g.config().max_waiting;
            while self.waiting.len() > max_waiting {
                let worst = self
                    .waiting
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, (sub, r))| {
                        (
                            g.effective_priority(r.priority, r.arrived, now),
                            std::cmp::Reverse(*sub),
                        )
                    })
                    .map(|(i, _)| i)
                    .expect("nonempty above the bound");
                let (_, req) = self.waiting.remove(worst);
                Self::shed_waiter(
                    g,
                    &mut self.metrics,
                    report,
                    &mut self.tracer,
                    &self.recorder,
                    ShedKind::QueueBound,
                    &req,
                    now,
                );
            }
        }
        Ok(())
    }

    /// Governor admission (the phase-2 replacement): weighted deficit
    /// round-robin across tenants with queued work. Each tenant, in
    /// ascending id order from a rotating start, is credited
    /// `weight × quantum` blocks and admits its best requests
    /// (effective-priority-major, submission-minor) while its credit,
    /// quota, rate bucket, and the Brownout gate allow. Rate- or
    /// quota-blocked tenants are *deferred* (their requests stay
    /// queued), never rejected — structured rejections only come from
    /// the queue bound and Shed mode in [`Self::govern`].
    fn govern_admit(&mut self, now: Instant, report: &mut StepReport) -> Result<()> {
        if !self.preempted.is_empty() {
            return Ok(()); // resume precedence, exactly as ungoverned
        }
        {
            let g = self.governor.as_mut().expect("governor attached");
            // rung 2 — Critical pauses admission entirely: reclaim and
            // the reactive preemption path drain the pool first
            if g.level() >= PressureLevel::Critical || g.mode() == ServeMode::Shed {
                return Ok(());
            }
        }

        let mut tenants: Vec<TenantId> = self.waiting.iter().map(|(_, r)| r.tenant).collect();
        tenants.sort_unstable();
        tenants.dedup();
        let g = self.governor.as_mut().expect("governor attached");
        // classic DRR: tenants with nothing queued forfeit their credit
        for t in g.tenant_ids() {
            if !tenants.contains(&t) {
                g.reset_deficit(t);
            }
        }
        if tenants.is_empty() {
            return Ok(());
        }
        let mode = g.mode();
        let start = g.rr_start(tenants.len());
        g.advance_rr();

        'round: for k in 0..tenants.len() {
            let t = tenants[(start + k) % tenants.len()];
            g.charge_deficit(t, now);
            loop {
                if self.running.len() >= self.cfg.max_running {
                    break 'round;
                }
                let Some(i) = self
                    .waiting
                    .iter()
                    .enumerate()
                    .filter(|(_, (_, r))| r.tenant == t)
                    .max_by_key(|(_, (sub, r))| {
                        (
                            g.effective_priority(r.priority, r.arrived, now),
                            std::cmp::Reverse(*sub),
                        )
                    })
                    .map(|(i, _)| i)
                else {
                    g.reset_deficit(t);
                    break;
                };
                let (_, ref req) = self.waiting[i];
                let eff = g.effective_priority(req.priority, req.arrived, now);
                if mode == ServeMode::Brownout && eff < g.config().brownout_min_priority {
                    // aging raises `eff` while it waits, so patient
                    // low-priority requests pass this gate eventually
                    g.metrics.brownout_deferred += 1;
                    break;
                }
                let budget = if mode == ServeMode::Brownout {
                    req.max_new_tokens.min(g.config().brownout_max_tokens)
                } else {
                    req.max_new_tokens
                };
                // quota charges the worst case: everything this
                // sequence could ever hold, reserved up front
                let need = self.kv.config().blocks_for_tokens(req.prompt.len() + budget + 1);
                if !g.quota_allows(t, need, now) {
                    g.metrics.quota_deferred += 1;
                    g.metrics.tenant(t).quota_deferred += 1;
                    if let Some(rc) = &self.recorder {
                        rc.record(FlightEvent::QuotaReject { tenant: t, req: req.id });
                    }
                    break;
                }
                if !g.rate_peek(t, now) {
                    g.metrics.rate_deferred += 1;
                    g.metrics.tenant(t).rate_deferred += 1;
                    break;
                }
                if g.deficit(t) < need {
                    break; // credit spent — next round tops it up
                }
                if !self.kv.admission_plan(&req.prompt).fits() {
                    break 'round; // the pool is the bottleneck, not fairness
                }

                // commit — mirrors the ungoverned admission body
                let (_, mut req) = self.waiting.remove(i);
                if budget < req.max_new_tokens {
                    req.max_new_tokens = budget;
                    g.metrics.clamped_budgets += 1;
                }
                let ctx = req.trace;
                let t0 = Self::trace_now_ns(&self.tracer);
                let pre = Self::restore_ledger(&self.kv);
                let matched = self.kv.register_with_prefix(req.id, &req.prompt)?;
                Self::attribute_restore(&mut self.tracer, &self.kv, ctx, t0, pre);
                self.kv.ensure_capacity(req.id, req.prompt.len() + 1)?;
                for &tok in &req.prompt[matched..] {
                    self.kv.write_token(req.id, tok)?;
                }
                self.kv.insert_prefix(req.id, &req.prompt)?;
                if self.kv.prefix_enabled() {
                    self.metrics.prefix_lookups += 1;
                    if matched > 0 {
                        self.metrics.prefix_hits += 1;
                        self.metrics.saved_prefill_tokens += matched as u64;
                    }
                }
                g.commit_admission(t, need, req.arrived, now);
                self.running.push(ActiveSeq {
                    tokens: req.prompt.clone(),
                    admit_seq: self.admit_counter,
                    reserved_blocks: need,
                    scored_upto: matched,
                    first_token_at: None,
                    last_token_at: now,
                    preemptions: 0,
                    req,
                });
                self.admit_counter += 1;
                self.metrics.admitted += 1;
                report.admitted += 1;
                Self::trace_enter(&mut self.tracer, ctx, Phase::Prefill);
            }
        }
        Ok(())
    }

    /// One scheduling iteration (see the module docs for the phases).
    pub fn step<E: IterationEngine>(&mut self, engine: &mut E) -> Result<StepReport> {
        let mut report = StepReport::default();

        // 0. shed expired waiters before anything admits: a request
        // whose deadline passed while queued gets a structured
        // `Expired` response and never touches the KV pool (no
        // register, so the leak check stays trivially clean)
        let now = self.clock.now();
        let mut w = 0;
        while w < self.waiting.len() {
            match self.waiting[w].1.deadline {
                Some(d) if now >= d => {
                    let (_, req) = self.waiting.remove(w);
                    self.metrics.expired += 1;
                    if let Some(rc) = &self.recorder {
                        rc.record(FlightEvent::Shed {
                            req: req.id,
                            kind: ShedKind::Expired,
                        });
                    }
                    let trace = Self::trace_close(&mut self.tracer, req.trace);
                    let mut resp = GenResponse::expired(&req, now);
                    resp.trace = trace;
                    report.responses.push(resp);
                }
                _ => w += 1,
            }
        }

        // 0b. governor pre-pass: observe the pool, run the proactive
        // cascade rungs (reclaim / cancel / queue bound). `None` keeps
        // the pre-governor behaviour byte-identical.
        if self.governor.is_some() {
            self.govern(now, &mut report)?;
        }

        // 1. resume, oldest preemption first (head-of-line). The plan
        // charges only what restore will actually allocate: shared
        // blocks still hot under the trie relink for free.
        while let Some(front) = self.preempted.front() {
            if self.running.len() >= self.cfg.max_running {
                break;
            }
            let id = front.req.id;
            let len = front.tokens.len();
            let ctx = front.req.trace;
            let resumed_phase = if front.first_token_at.is_some() {
                Phase::Decode
            } else {
                Phase::Prefill
            };
            if !self.kv.resume_plan(id, len + 1)?.fits() {
                break;
            }
            Self::trace_enter(&mut self.tracer, ctx, Phase::KvRestore);
            let t0 = Self::trace_now_ns(&self.tracer);
            let pre = Self::restore_ledger(&self.kv);
            self.kv.restore(id, self.pool.as_deref())?;
            Self::attribute_restore(&mut self.tracer, &self.kv, ctx, t0, pre);
            Self::trace_enter(&mut self.tracer, ctx, resumed_phase);
            self.kv.ensure_capacity(id, len + 1)?;
            let seq = self.preempted.pop_front().expect("front checked");
            self.running.push(seq);
            self.metrics.resumes += 1;
            report.resumed += 1;
        }

        // 2. admit — but never past sequences still waiting to resume.
        // Demand is sized by the admission plan, which consults the
        // prefix index first: a prompt whose prefix is already resident
        // is charged only its private *suffix* blocks, so shared
        // prefixes keep admitting under pressure that would starve the
        // naive `prompt + 1` sizing. With a governor attached the
        // priority-major loop below is replaced by weighted deficit
        // round-robin across tenants (quota / rate / brownout gated).
        if self.governor.is_some() {
            self.govern_admit(now, &mut report)?;
        }
        while self.governor.is_none()
            && self.preempted.is_empty()
            && self.running.len() < self.cfg.max_running
        {
            let Some(i) = self.pick_waiting() else { break };
            if !self.kv.admission_plan(&self.waiting[i].1.prompt).fits() {
                break;
            }
            let (_, req) = self.waiting.remove(i);
            let ctx = req.trace;
            let t0 = Self::trace_now_ns(&self.tracer);
            let pre = Self::restore_ledger(&self.kv);
            let matched = self.kv.register_with_prefix(req.id, &req.prompt)?;
            Self::attribute_restore(&mut self.tracer, &self.kv, ctx, t0, pre);
            self.kv.ensure_capacity(req.id, req.prompt.len() + 1)?;
            for &t in &req.prompt[matched..] {
                self.kv.write_token(req.id, t)?;
            }
            self.kv.insert_prefix(req.id, &req.prompt)?;
            if self.kv.prefix_enabled() {
                self.metrics.prefix_lookups += 1;
                if matched > 0 {
                    self.metrics.prefix_hits += 1;
                    self.metrics.saved_prefill_tokens += matched as u64;
                }
            }
            let now = self.clock.now();
            self.running.push(ActiveSeq {
                tokens: req.prompt.clone(),
                admit_seq: self.admit_counter,
                reserved_blocks: 0,
                scored_upto: matched,
                first_token_at: None,
                last_token_at: now,
                preemptions: 0,
                req,
            });
            self.admit_counter += 1;
            self.metrics.admitted += 1;
            report.admitted += 1;
            Self::trace_enter(&mut self.tracer, ctx, Phase::Prefill);
        }

        // 3. grow every survivor by one token of capacity, preempting
        // under pressure
        let mut i = 0;
        while i < self.running.len() {
            let id = self.running[i].req.id;
            let want = self.running[i].tokens.len() + 1;
            loop {
                match self.kv.ensure_capacity(id, want) {
                    Ok(_) => {
                        i += 1;
                        break;
                    }
                    Err(KvError::OutOfBlocks { .. }) => {
                        let v = self.pick_victim().expect("running is nonempty here");
                        self.evict_running(v)?;
                        report.preempted += 1;
                        if v == i {
                            // self-preempted: the element now at `i` is
                            // the next sequence — do not advance
                            break;
                        }
                        if v < i {
                            i -= 1;
                        }
                        // retry the same sequence
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        }

        // 4. one ragged iteration over the survivors
        if self.running.is_empty() {
            self.step_epilogue();
            return Ok(report);
        }
        let batch = IterationBatch {
            slots: self
                .running
                .iter()
                .map(|s| SeqSlot {
                    seq: s.req.id,
                    tokens: &s.tokens,
                    pos: s.tokens.len(),
                    // prefill the engine still owes: everything written
                    // since this sequence was last scored (prefix-linked
                    // positions start charged — their prefill was free)
                    new_tokens: s.tokens.len() - s.scored_upto,
                })
                .collect(),
            pad_slots: 0,
        };
        let vocab = engine.vocab();
        let logits = engine.step(&batch, &self.kv)?;
        debug_assert_eq!(logits.len(), self.running.len() * vocab);
        drop(batch); // release the borrows of `running` before mutating
        let next: Vec<i32> = (0..self.running.len())
            .map(|i| argmax(&logits[i * vocab..(i + 1) * vocab]))
            .collect();
        report.ran = self.running.len();
        self.metrics.record_iteration(self.running.len(), 0);

        let now = self.clock.now();
        let mut idx = 0;
        // `row` tracks the iteration's original slot order: removals
        // shift `running`, but every surviving sequence must consume
        // the logits row it was scored with
        let mut row = 0;
        while idx < self.running.len() {
            let tok = next[row];
            row += 1;
            let seq = &mut self.running[idx];
            seq.scored_upto = seq.tokens.len();
            seq.tokens.push(tok);
            self.kv.write_token(seq.req.id, tok)?;
            self.metrics.tokens_generated += 1;
            match seq.first_token_at {
                None => {
                    seq.first_token_at = Some(now);
                    self.metrics
                        .ttft
                        .record(now.saturating_duration_since(seq.req.arrived).as_secs_f64());
                    // first token: prefill is paid for, the span decodes
                    // from here on
                    let ctx = seq.req.trace;
                    Self::trace_enter(&mut self.tracer, ctx, Phase::Decode);
                }
                Some(_) => {
                    self.metrics
                        .tpot
                        .record(now.saturating_duration_since(seq.last_token_at).as_secs_f64());
                }
            }
            seq.last_token_at = now;
            if seq.finished() {
                let seq = self.running.remove(idx);
                self.kv.release(seq.req.id)?;
                self.metrics.finished += 1;
                if let Some(g) = self.governor.as_mut() {
                    g.release_reservation(seq.req.tenant, seq.reserved_blocks, now);
                    g.metrics.tenant(seq.req.tenant).completed += 1;
                }
                report.responses.push(GenResponse {
                    id: seq.req.id,
                    tokens: seq.tokens[seq.req.prompt.len()..].to_vec(),
                    ttft_s: seq
                        .first_token_at
                        .expect("finished sequences generated")
                        .saturating_duration_since(seq.req.arrived)
                        .as_secs_f64(),
                    latency_s: now.saturating_duration_since(seq.req.arrived).as_secs_f64(),
                    preemptions: seq.preemptions,
                    finish: FinishReason::Completed,
                    trace: Self::trace_close(&mut self.tracer, seq.req.trace),
                });
            } else {
                idx += 1;
            }
        }
        self.metrics.peak_running = self.metrics.peak_running.max(report.ran);
        self.step_epilogue();
        Ok(report)
    }

    /// Per-step telemetry settlement, run on every `step` exit path:
    /// refresh the prefix-tier census gauges (satellite of the tier
    /// census that `kv-sim` alone used to see) and flush any armed
    /// flight-recorder dump *after* this step's consequences (shed
    /// responses, preemptions) landed in the ring.
    fn step_epilogue(&mut self) {
        if let Some(census) = self.kv.prefix_census() {
            self.metrics.record_census(&census);
        }
        if let Some(rc) = &self.recorder {
            rc.flush(); // no-op unless a dump is armed
        }
    }

    /// Drive [`Self::step`] until nothing is queued, surfacing a stall
    /// (a sequence that can never fit the pool) as an error instead of
    /// spinning.
    pub fn run_to_completion<E: IterationEngine>(
        &mut self,
        engine: &mut E,
    ) -> Result<Vec<GenResponse>> {
        let mut out = Vec::new();
        while self.has_work() {
            let report = self.step(engine)?;
            if report.no_progress() && self.has_work() {
                return Err(anyhow!(
                    "continuous scheduler stalled: a queued sequence cannot ever fit \
                     the block pool (pool {} blocks)",
                    self.kv.config().n_blocks
                ));
            }
            out.extend(report.responses);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Static batch-to-completion baseline
// ---------------------------------------------------------------------------

/// The pre-continuous policy this subsystem replaces, kept as the bench
/// baseline and the identity oracle: chunk requests into groups of
/// `max_batch` (arrival order), preallocate each member's worst-case KV
/// (`prompt + max_new_tokens` — no paging, no overcommit), and run the
/// whole group to completion before the next group starts. Sequences
/// that finish early stay as dead `pad_slots` until the group drains —
/// the rectangle waste continuous scheduling eliminates. With
/// `respect_arrivals`, the runner sleeps until a group's last member
/// has arrived (the open-loop TTFT cost of batch formation).
pub fn run_static<E: IterationEngine>(
    engine: &mut E,
    kv: &mut KvCacheManager,
    requests: &[GenRequest],
    max_batch: usize,
    clock: &dyn Clock,
    metrics: &mut SchedulerMetrics,
    respect_arrivals: bool,
) -> Result<Vec<GenResponse>> {
    assert!(max_batch > 0, "zero-width static batch");
    let vocab = engine.vocab();
    let mut responses = Vec::with_capacity(requests.len());
    for group in requests.chunks(max_batch) {
        if respect_arrivals {
            // batch formation: the group cannot start before its last
            // member exists (real sleep — open-loop drives use the
            // system clock)
            let latest = group.iter().map(|r| r.arrived).max().expect("nonempty");
            let now = Instant::now();
            if latest > now {
                std::thread::sleep(latest - now);
            }
        }
        // prefill with worst-case preallocation
        for r in group {
            kv.register(r.id)?;
            kv.ensure_capacity(r.id, r.prompt.len() + r.max_new_tokens)?;
            for &t in &r.prompt {
                kv.write_token(r.id, t)?;
            }
            metrics.admitted += 1;
        }
        let mut tokens: Vec<Vec<i32>> = group.iter().map(|r| r.prompt.clone()).collect();
        let mut first: Vec<Option<Instant>> = vec![None; group.len()];
        let mut last: Vec<Instant> = vec![clock.now(); group.len()];
        // static batching never shares: every prompt prefills in full
        let mut scored: Vec<usize> = vec![0; group.len()];
        loop {
            let live: Vec<usize> = (0..group.len())
                .filter(|&i| tokens[i].len() - group[i].prompt.len() < group[i].max_new_tokens)
                .collect();
            if live.is_empty() {
                break;
            }
            let batch = IterationBatch {
                slots: live
                    .iter()
                    .map(|&i| SeqSlot {
                        seq: group[i].id,
                        tokens: &tokens[i],
                        pos: tokens[i].len(),
                        new_tokens: tokens[i].len() - scored[i],
                    })
                    .collect(),
                pad_slots: group.len() - live.len(),
            };
            let logits = engine.step(&batch, kv)?;
            metrics.record_iteration(live.len(), group.len() - live.len());
            let now = clock.now();
            for (row, &i) in live.iter().enumerate() {
                let tok = argmax(&logits[row * vocab..(row + 1) * vocab]);
                scored[i] = tokens[i].len();
                tokens[i].push(tok);
                kv.write_token(group[i].id, tok)?;
                metrics.tokens_generated += 1;
                match first[i] {
                    None => {
                        first[i] = Some(now);
                        metrics.ttft.record(
                            now.saturating_duration_since(group[i].arrived).as_secs_f64(),
                        );
                    }
                    Some(_) => {
                        metrics
                            .tpot
                            .record(now.saturating_duration_since(last[i]).as_secs_f64());
                    }
                }
                last[i] = now;
                if tokens[i].len() - group[i].prompt.len() == group[i].max_new_tokens {
                    metrics.finished += 1;
                    responses.push(GenResponse {
                        id: group[i].id,
                        tokens: tokens[i][group[i].prompt.len()..].to_vec(),
                        ttft_s: now.saturating_duration_since(group[i].arrived).as_secs_f64(),
                        latency_s: now
                            .saturating_duration_since(group[i].arrived)
                            .as_secs_f64(),
                        preemptions: 0,
                        finish: FinishReason::Completed,
                        trace: None,
                    });
                }
            }
        }
        metrics.peak_running = metrics.peak_running.max(group.len());
        // the whole group's memory is held until the group drains
        for r in group {
            kv.release(r.id)?;
        }
    }
    Ok(responses)
}

// ---------------------------------------------------------------------------
// Threaded wrapper — the continuous coordinator surface
// ---------------------------------------------------------------------------

/// Everything the continuous coordinator hands back at shutdown.
pub struct ContinuousReport<E> {
    pub engine: E,
    /// responses not collected before shutdown
    pub responses: Vec<GenResponse>,
    pub metrics: SchedulerMetrics,
    pub kv_stats: KvStats,
    /// overload-governor observability, when one was attached
    pub pressure: Option<PressureMetrics>,
    /// the zero-leak invariant at shutdown (`Err` describes the leak)
    pub leak_check: Result<(), String>,
}

type SchedulerOutcome<E> = (
    E,
    SchedulerMetrics,
    KvStats,
    Option<PressureMetrics>,
    Result<(), String>,
    Option<anyhow::Error>,
);

/// The continuous-batching sibling of
/// [`crate::coordinator::PipelinedServer`]: submissions from any thread,
/// a scheduler thread running iterations, responses streamed back.
/// Construction spawns the scheduler thread; [`Self::shutdown`] drains
/// and joins it.
pub struct ContinuousServer<E: IterationEngine + 'static> {
    req_tx: Option<channel::Sender<GenRequest>>,
    resp_rx: mpsc::Receiver<GenResponse>,
    handle: Option<JoinHandle<SchedulerOutcome<E>>>,
    beat: Heartbeat,
}

/// How long the scheduler thread sleeps on an idle queue before
/// re-checking for shutdown.
const IDLE_WAIT: Duration = Duration::from_millis(5);

impl<E: IterationEngine + 'static> ContinuousServer<E> {
    pub fn new(engine: E, sched: ContinuousScheduler) -> Self {
        let (req_tx, req_rx) = channel::bounded::<GenRequest>(4096);
        let (resp_tx, resp_rx) = mpsc::channel::<GenResponse>();
        let beat = Heartbeat::new();
        let handle = std::thread::spawn({
            let beat = beat.clone();
            move || {
            let mut engine = engine;
            let mut sched = sched;
            let mut first_err: Option<anyhow::Error> = None;
            loop {
                // one pulse per scheduler iteration: the watchdog-style
                // liveness signal `health()` reports on
                beat.pulse();
                while let Some(r) = req_rx.try_recv() {
                    sched.submit(r);
                }
                if sched.has_work() {
                    match sched.step(&mut engine) {
                        Ok(report) => {
                            let stalled = report.no_progress() && sched.has_work();
                            for r in report.responses {
                                // receiver lives in the server handle
                                let _ = resp_tx.send(r);
                            }
                            if stalled {
                                // arrivals cannot free blocks, so a
                                // no-progress step with queued work is
                                // permanent (head sequence > pool)
                                first_err = Some(anyhow!(
                                    "continuous scheduler stalled: a queued sequence cannot \
                                     ever fit the block pool"
                                ));
                                break;
                            }
                        }
                        Err(e) => {
                            first_err = Some(e);
                            break;
                        }
                    }
                } else {
                    match req_rx.recv_timeout(IDLE_WAIT) {
                        Ok(r) => sched.submit(r),
                        Err(RecvTimeoutError::Closed) => break,
                        Err(RecvTimeoutError::Timeout) => {}
                    }
                }
            }
            let leak = sched.kv.leak_check();
            let pressure = sched.governor.as_ref().map(|g| g.metrics.clone());
            (engine, sched.metrics.clone(), sched.kv.stats().clone(), pressure, leak, first_err)
        }});
        Self {
            req_tx: Some(req_tx),
            resp_rx,
            handle: Some(handle),
            beat,
        }
    }

    /// The scheduler stage's liveness: thread running (join-handle
    /// check) plus its heartbeat age. The continuous coordinator owns
    /// its engine outright, so there is no restart path — supervision
    /// here is observe-and-report, feeding the same [`StageHealth`]
    /// surface as [`crate::coordinator::SupervisedServer`].
    pub fn health(&self) -> StageHealth {
        StageHealth {
            name: "scheduler".into(),
            alive: self.handle.as_ref().map(|h| !h.is_finished()).unwrap_or(false),
            beats: self.beat.beats(),
            last_beat_age: self.beat.age(),
            restarts: 0,
        }
    }

    /// Enqueue a request (never blocks on iteration execution).
    pub fn submit(&self, req: GenRequest) {
        if let Some(tx) = &self.req_tx {
            let _ = tx.send(req);
        }
    }

    /// Responses completed so far (non-blocking).
    pub fn collect_ready(&self) -> Vec<GenResponse> {
        let mut out = Vec::new();
        while let Ok(r) = self.resp_rx.try_recv() {
            out.push(r);
        }
        out
    }

    /// Stop accepting requests, finish everything queued, and join the
    /// scheduler thread. Fails with the scheduler's first error.
    pub fn shutdown(mut self) -> Result<ContinuousReport<E>> {
        drop(self.req_tx.take());
        let (engine, metrics, kv_stats, pressure, leak_check, first_err) = self
            .handle
            .take()
            .expect("shutdown joins once")
            .join()
            .map_err(|_| anyhow!("scheduler thread panicked"))?;
        if let Some(e) = first_err {
            return Err(e);
        }
        let mut responses = Vec::new();
        while let Ok(r) = self.resp_rx.try_recv() {
            responses.push(r);
        }
        Ok(ContinuousReport {
            engine,
            responses,
            metrics,
            kv_stats,
            pressure,
            leak_check,
        })
    }
}

impl<E: IterationEngine + 'static> Drop for ContinuousServer<E> {
    fn drop(&mut self) {
        drop(self.req_tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Fp8Format;
    use crate::scheduler::iteration::SyntheticIterationEngine;
    use crate::scheduler::{SimClock, SystemClock};
    use crate::util::prng::Xoshiro256;
    use std::collections::HashMap;

    fn kv_cfg(n_blocks: usize) -> KvCacheConfig {
        KvCacheConfig {
            block_tokens: 4,
            bytes_per_token: 32,
            n_blocks,
            format: Fp8Format::E4M3,
            prefix: None,
        }
    }

    fn kv_cfg_prefix(n_blocks: usize) -> KvCacheConfig {
        kv_cfg(n_blocks).with_prefix(crate::scheduler::prefix::PrefixCacheConfig::default())
    }

    fn reqs(n: u64, vocab: usize, prompt_len: usize, max_new: usize, seed: u64) -> Vec<GenRequest> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|id| {
                GenRequest::new(
                    id,
                    (0..prompt_len)
                        .map(|_| rng.next_below(vocab as u64) as i32)
                        .collect(),
                    max_new,
                )
            })
            .collect()
    }

    fn by_id(responses: Vec<GenResponse>) -> HashMap<u64, GenResponse> {
        responses.into_iter().map(|r| (r.id, r)).collect()
    }

    #[test]
    fn continuous_matches_static_under_preemption() {
        let vocab = 48;
        let requests = reqs(10, vocab, 6, 8, 3);
        // static oracle: a huge pool, batches of 3
        let mut eng_s = SyntheticIterationEngine::instant(vocab);
        let mut kv_s = KvCacheManager::new(kv_cfg(256));
        let mut ms = SchedulerMetrics::default();
        let want = by_id(
            run_static(&mut eng_s, &mut kv_s, &requests, 3, &SystemClock, &mut ms, false)
                .unwrap(),
        );
        kv_s.leak_check().unwrap();

        // continuous: pool so tight preemption must fire
        let mut eng_c = SyntheticIterationEngine::instant(vocab);
        let mut sched = ContinuousScheduler::new(
            SchedConfig { max_running: 8 },
            kv_cfg(12),
            SimClock::new(),
        );
        for r in &requests {
            sched.submit(r.clone());
        }
        let got = by_id(sched.run_to_completion(&mut eng_c).unwrap());
        assert!(
            sched.metrics.preemptions > 0,
            "pool of 12 blocks must force preemption"
        );
        assert!(sched.kv.stats().restores > 0);
        sched.kv.leak_check().unwrap();

        assert_eq!(got.len(), want.len());
        for (id, w) in &want {
            let g = &got[id];
            assert_eq!(g.tokens, w.tokens, "request {id} diverged");
            assert_eq!(g.tokens.len(), 8);
        }
    }

    #[test]
    fn prefix_cache_keeps_identity_with_static_under_preemption() {
        use crate::scheduler::workload::{shared_prefix_requests, SharedPrefixWorkload};
        let vocab = 48;
        let w = SharedPrefixWorkload {
            tenants: 2,
            system_tokens: 12,
            user_tokens: 4,
            // long enough that every sequence outgrows its admission
            // capacity (prompt+1 → 5 blocks = 20 tokens) — growth under
            // a full pool is what forces preemption
            gen_min: 6,
            gen_max: 10,
            vocab: vocab as i32 - 1,
        };
        let requests = shared_prefix_requests(&w, 16, 5, Instant::now(), Duration::ZERO);

        // static oracle: huge pool, no prefix cache
        let mut eng_s = SyntheticIterationEngine::instant(vocab);
        let mut kv_s = KvCacheManager::new(kv_cfg(256));
        let mut ms = SchedulerMetrics::default();
        let want = by_id(
            run_static(&mut eng_s, &mut kv_s, &requests, 4, &SystemClock, &mut ms, false)
                .unwrap(),
        );
        kv_s.leak_check().unwrap();

        // continuous with the prefix cache, pool tight enough to preempt
        let mut eng_c = SyntheticIterationEngine::instant(vocab);
        let mut sched = ContinuousScheduler::new(
            SchedConfig { max_running: 6 },
            kv_cfg_prefix(14),
            SimClock::new(),
        );
        for r in &requests {
            sched.submit(r.clone());
        }
        let got = by_id(sched.run_to_completion(&mut eng_c).unwrap());
        assert_eq!(got.len(), want.len());
        for (id, wr) in &want {
            assert_eq!(got[id].tokens, wr.tokens, "request {id} diverged");
        }
        assert!(sched.metrics.prefix_hits > 0, "shared prefixes must hit");
        assert!(sched.metrics.saved_prefill_tokens > 0);
        assert!(
            sched.metrics.preemptions > 0,
            "pool of 14 blocks must force preemption"
        );
        assert!(
            sched.kv.stats().shared_blocks_retained > 0,
            "preempted sharers leave shared blocks under the trie"
        );
        sched.kv.leak_check().unwrap();
    }

    #[test]
    fn admission_demands_only_the_suffix_for_hitting_prompts() {
        let vocab = 32;
        let mut sched = ContinuousScheduler::new(
            SchedConfig { max_running: 4 },
            kv_cfg_prefix(8),
            SimClock::new(),
        );
        let mut eng = SyntheticIterationEngine::instant(vocab);
        let prompt: Vec<i32> = (1..=8).collect(); // 2 full blocks

        // request A publishes the prefix, finishes, releases its blocks
        sched.submit(GenRequest::new(0, prompt.clone(), 2));
        while sched.has_work() {
            sched.step(&mut eng).unwrap();
        }
        assert_eq!(sched.kv.trie_hot_blocks(), 2, "prefix survives A");

        // a filler pins 4 blocks; free = 8 − 2 (trie) − 4 = 2
        sched.submit(GenRequest::new(1, vec![90; 12], 16));
        sched.step(&mut eng).unwrap();
        assert_eq!(sched.running_ids(), vec![1]);
        assert_eq!(sched.kv.free_blocks(), 2);

        // B re-sends the shared prompt. Naive demand is 3 blocks (> 2
        // free) — the suffix-aware plan charges 1 and must admit.
        sched.submit(GenRequest::new(2, prompt.clone(), 2));
        let r = sched.step(&mut eng).unwrap();
        assert_eq!(r.admitted, 1, "hitting prompt admits on suffix demand");
        assert!(sched.running_ids().contains(&2));
        assert_eq!(sched.metrics.prefix_hits, 1);
        assert_eq!(sched.metrics.saved_prefill_tokens, 8);
        while sched.has_work() {
            sched.step(&mut eng).unwrap();
        }
        sched.kv.leak_check().unwrap();
    }

    #[test]
    fn admission_is_priority_major_submission_minor() {
        let vocab = 16;
        let clock = SimClock::new();
        let mut sched = ContinuousScheduler::new(
            SchedConfig { max_running: 2 },
            kv_cfg(64),
            clock,
        );
        let mk = |id: u64, p: u8| GenRequest::new(id, vec![1, 2], 4).with_priority(p);
        sched.submit(mk(0, 0));
        sched.submit(mk(1, 5));
        sched.submit(mk(2, 5));
        sched.submit(mk(3, 9));
        let mut eng = SyntheticIterationEngine::instant(vocab);
        sched.step(&mut eng).unwrap();
        // width 2: highest priority first, then submission order
        assert_eq!(sched.running_ids(), vec![3, 1]);
    }

    #[test]
    fn victim_is_lowest_priority_newest_admission() {
        let vocab = 16;
        // 4-token blocks; prompt 3 + 1 headroom = 1 block each; pool of 3
        // blocks fits three 1-block seqs, next growth forces eviction
        let mut sched = ContinuousScheduler::new(
            SchedConfig { max_running: 3 },
            kv_cfg(3),
            SimClock::new(),
        );
        let mk = |id: u64, p: u8| GenRequest::new(id, vec![1, 2, 3], 6).with_priority(p);
        sched.submit(mk(10, 1));
        sched.submit(mk(11, 0));
        sched.submit(mk(12, 0));
        let mut eng = SyntheticIterationEngine::instant(vocab);
        // step 1: all three admitted (1 block each), each generates
        // token 4 of 4 — block full
        let r = sched.step(&mut eng).unwrap();
        assert_eq!(r.admitted, 3);
        assert_eq!(sched.running_ids(), vec![10, 11, 12]);
        // step 2: everyone needs a second block; pool is empty. Victim
        // must be priority 0, newest admission → 12; freeing one block
        // lets 10 grow, then 11 needs one and evicts... the next-newest
        // priority-0 seq, 11 itself → self-preempt.
        let r = sched.step(&mut eng).unwrap();
        assert!(r.preempted >= 1);
        assert!(sched.preempted_ids().contains(&12), "newest low-priority first");
        assert!(sched.running_ids().contains(&10), "high priority survives");
        // drain fully; identity with an untouched run is covered by the
        // identity test — here just check termination + zero leaks
        let rest = sched.run_to_completion(&mut eng).unwrap();
        assert_eq!(rest.len(), 3);
        sched.kv.leak_check().unwrap();
    }

    #[test]
    fn preempted_resume_before_new_admissions() {
        let vocab = 16;
        // 4-token blocks, pool of 4: each seq needs 2 blocks at admission
        // (prompt 4 + headroom) and 3 at its full length 12 — so two
        // running seqs fill the pool and the first growth past 8 tokens
        // must evict the other; a third request must then queue behind
        // the preempted one.
        let mut sched = ContinuousScheduler::new(
            SchedConfig { max_running: 2 },
            kv_cfg(4),
            SimClock::new(),
        );
        sched.submit(GenRequest::new(0, vec![1; 4], 8));
        sched.submit(GenRequest::new(1, vec![2; 4], 8));
        let mut eng = SyntheticIterationEngine::instant(vocab);
        sched.step(&mut eng).unwrap();
        assert_eq!(sched.running_ids(), vec![0, 1]);
        // a newcomer while the pool is committed
        sched.submit(GenRequest::new(2, vec![3; 4], 8));
        let mut preempt_seen = false;
        let mut responses = Vec::new();
        for _ in 0..128 {
            if !sched.has_work() {
                break;
            }
            responses.extend(sched.step(&mut eng).unwrap().responses);
            if !sched.preempted_ids().is_empty() {
                preempt_seen = true;
                // while anything waits to resume, nothing new admits
                assert!(
                    !sched.running_ids().contains(&2),
                    "admission overtook a preempted sequence"
                );
            }
        }
        assert!(!sched.has_work(), "drained");
        assert!(preempt_seen, "growth past the pool must preempt");
        assert_eq!(responses.len(), 3);
        sched.kv.leak_check().unwrap();
    }

    #[test]
    fn expired_waiters_shed_exactly_at_deadline() {
        let vocab = 16;
        let clock = SimClock::new();
        let mut sched = ContinuousScheduler::new(
            SchedConfig { max_running: 1 },
            kv_cfg(64),
            clock.clone(),
        );
        let t0 = clock.now();
        // width 1: id 0 occupies the slot, so 1–3 queue. 1 carries a
        // near deadline, 2 a distant one, 3 none.
        sched.submit(GenRequest::at(0, vec![1, 2], 4, t0));
        sched.submit(
            GenRequest::at(1, vec![1, 2], 4, t0).with_deadline(t0 + Duration::from_millis(10)),
        );
        sched.submit(
            GenRequest::at(2, vec![1, 2], 4, t0).with_deadline(t0 + Duration::from_secs(60)),
        );
        sched.submit(GenRequest::at(3, vec![1, 2], 4, t0));
        let mut eng = SyntheticIterationEngine::instant(vocab);

        // one tick before id 1's deadline: nothing sheds
        clock.advance(Duration::from_millis(10) - Duration::from_nanos(1));
        let r = sched.step(&mut eng).unwrap();
        assert!(r.responses.is_empty());
        assert_eq!(sched.metrics.expired, 0);

        // exactly at the deadline: shed (>= — mirrors the batcher)
        clock.advance(Duration::from_nanos(1));
        let r = sched.step(&mut eng).unwrap();
        assert_eq!(r.responses.len(), 1, "structured response for the shed request");
        assert_eq!(r.responses[0].id, 1);
        assert_eq!(r.responses[0].finish, FinishReason::Expired);
        assert!(r.responses[0].tokens.is_empty());
        assert!(!r.responses[0].is_complete());
        assert_eq!(sched.metrics.expired, 1);

        // everyone else — including far-deadline id 2 — completes
        let done = by_id(sched.run_to_completion(&mut eng).unwrap());
        assert_eq!(done.len(), 3);
        for id in [0u64, 2, 3] {
            assert_eq!(done[&id].finish, FinishReason::Completed);
            assert_eq!(done[&id].tokens.len(), 4, "request {id}");
        }
        // an expired request never registered KV, so nothing can leak
        sched.kv.leak_check().unwrap();
    }

    #[test]
    fn stall_surfaces_as_error_not_a_spin() {
        let vocab = 16;
        let mut sched = ContinuousScheduler::new(
            SchedConfig { max_running: 4 },
            kv_cfg(2),
            SimClock::new(),
        );
        // prompt needs 3 blocks + headroom, pool has 2 — can never fit
        sched.submit(GenRequest::new(0, vec![1; 12], 4));
        let mut eng = SyntheticIterationEngine::instant(vocab);
        let err = sched.run_to_completion(&mut eng).unwrap_err();
        assert!(err.to_string().contains("stalled"), "{err}");
    }

    #[test]
    fn threaded_server_matches_synchronous_run() {
        let vocab = 32;
        let requests = reqs(12, vocab, 5, 6, 9);

        let mut eng = SyntheticIterationEngine::instant(vocab);
        let mut sched = ContinuousScheduler::new(
            SchedConfig { max_running: 6 },
            kv_cfg(10),
            SimClock::new(),
        );
        for r in &requests {
            sched.submit(r.clone());
        }
        let want = by_id(sched.run_to_completion(&mut eng).unwrap());

        let server = ContinuousServer::new(
            SyntheticIterationEngine::instant(vocab),
            ContinuousScheduler::new(
                SchedConfig { max_running: 6 },
                kv_cfg(10),
                Arc::new(SystemClock),
            ),
        );
        let mut got = Vec::new();
        for r in &requests {
            server.submit(r.clone());
            got.extend(server.collect_ready());
        }
        let health = server.health();
        assert_eq!(health.name, "scheduler");
        assert!(health.alive, "scheduler thread live while serving");
        let report = server.shutdown().unwrap();
        got.extend(report.responses);
        report.leak_check.expect("zero leaked blocks");
        let got = by_id(got);
        assert_eq!(got.len(), 12);
        for (id, w) in &want {
            assert_eq!(got[id].tokens, w.tokens, "request {id}");
        }
        assert_eq!(report.metrics.finished, 12);
        assert_eq!(report.metrics.tokens_generated, 12 * 6);
    }

    #[test]
    fn threaded_server_surfaces_stall_errors() {
        let server = ContinuousServer::new(
            SyntheticIterationEngine::instant(8),
            ContinuousScheduler::new(
                SchedConfig { max_running: 2 },
                kv_cfg(1),
                Arc::new(SystemClock),
            ),
        );
        server.submit(GenRequest::new(0, vec![1; 32], 4));
        let err = server.shutdown().unwrap_err();
        assert!(err.to_string().contains("stalled"), "{err}");
    }

    #[test]
    fn static_runner_counts_padding_waste() {
        let vocab = 16;
        let mut eng = SyntheticIterationEngine::instant(vocab);
        let mut kv = KvCacheManager::new(kv_cfg(64));
        let mut m = SchedulerMetrics::default();
        // uneven budgets inside one group → dead slots while the long
        // one drains
        let requests = vec![
            GenRequest::new(0, vec![1, 2], 2),
            GenRequest::new(1, vec![3, 4], 10),
        ];
        let got = run_static(&mut eng, &mut kv, &requests, 2, &SystemClock, &mut m, false)
            .unwrap();
        assert_eq!(got.len(), 2);
        kv.leak_check().unwrap();
        assert_eq!(m.iterations, 10, "group runs to the longest member");
        assert_eq!(m.slot_tokens, 12, "2 + 10 live tokens");
        assert_eq!(m.slot_capacity, 20, "2 slots × 10 iterations");
        assert!(m.occupancy() < 0.7, "padding waste visible");
    }
}
