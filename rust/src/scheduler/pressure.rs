//! Overload governor: watermark-driven KV pressure cascade, per-tenant
//! quotas with token-bucket rate limiting, weighted deficit-round-robin
//! admission with priority aging, and the hysteretic Normal → Brownout
//! → Shed serving-mode machine.
//!
//! ## Why this exists
//!
//! Without a governor, "out of KV blocks" is an *emergent* failure: the
//! scheduler preempts whoever is cheapest, queues grow without bound,
//! and one noisy tenant can starve everyone else. This module turns
//! overload into a deterministic, observable degradation ladder:
//!
//! 1. **High watermark** — proactively compress idle prefix-trie
//!    blocks through the codec registry
//!    ([`super::kv_cache::KvCacheManager::reclaim_idle`], the same path
//!    `take_free` uses reactively). Cheap because K/V caches
//!    concentrate exponents exactly like weights (Heilper & Singer
//!    2025), so the compressed tier is the paper's §3.2 probe applied
//!    as a pressure-release valve.
//! 2. **Critical watermark** — pause new admissions; preemption (the
//!    reactive `OutOfBlocks` path) drains the pool while the bounded
//!    waiting queue sheds its lowest-effective-priority tail with
//!    structured [`super::policy::FinishReason::Rejected`] responses.
//! 3. **Shed mode** — the hysteretic [`ModeMachine`] has decided the
//!    overload is sustained: every queued request is rejected
//!    structurally until occupancy falls back through the exit
//!    threshold.
//!
//! Degradation stays *structurally lossless* in the DFloat11 sense: a
//! request is served bit-identically or rejected with a typed reason —
//! never truncated silently, never corrupted.
//!
//! Every decision here is a pure function of pool statistics plus
//! instants handed in by the caller (who reads them from the injected
//! [`super::Clock`]), so [`super::SimClock`] replays — and the
//! `sim_pressure.py` verify port — are exact.

use crate::coordinator::metrics::LatencyHistogram;
use crate::telemetry::recorder::{DumpReason, FlightEvent, FlightRecorder};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Tenant identity carried by requests. Tenant 0 is the default for
/// callers that predate multi-tenancy.
pub type TenantId = u32;

/// Instantaneous pool pressure, classified by [`Watermarks`]. Ordered:
/// `Low < High < Critical`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum PressureLevel {
    /// below the high watermark — no governor action
    Low,
    /// at or above the high watermark — proactive idle-block reclaim
    High,
    /// at or above the critical watermark — admissions paused
    Critical,
}

impl PressureLevel {
    pub fn name(self) -> &'static str {
        match self {
            PressureLevel::Low => "low",
            PressureLevel::High => "high",
            PressureLevel::Critical => "critical",
        }
    }
}

/// Occupancy thresholds (fractions of the block pool) classifying
/// [`PressureLevel`]. `>=` at each boundary, mirroring the scheduler's
/// deadline semantics.
#[derive(Debug, Clone, Copy)]
pub struct Watermarks {
    pub high: f64,
    pub critical: f64,
}

impl Default for Watermarks {
    fn default() -> Self {
        Self { high: 0.70, critical: 0.90 }
    }
}

impl Watermarks {
    /// Classify `used / total` occupancy. `total == 0` is Low (an
    /// empty pool cannot be pressured).
    pub fn classify(&self, used: usize, total: usize) -> PressureLevel {
        let occ = occupancy(used, total);
        if occ >= self.critical {
            PressureLevel::Critical
        } else if occ >= self.high {
            PressureLevel::High
        } else {
            PressureLevel::Low
        }
    }
}

/// Pool occupancy as a fraction in `[0, 1]`.
pub fn occupancy(used: usize, total: usize) -> f64 {
    if total == 0 {
        0.0
    } else {
        used as f64 / total as f64
    }
}

/// A deterministic token bucket: `refill_per_s` tokens per second up to
/// `capacity`, driven entirely by caller-supplied instants (no hidden
/// clock reads — `SimClock` replays are exact).
#[derive(Debug, Clone)]
pub struct TokenBucket {
    capacity: f64,
    refill_per_s: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// A full bucket, stamped at `now`.
    pub fn new(capacity: f64, refill_per_s: f64, now: Instant) -> Self {
        assert!(capacity > 0.0, "zero-capacity bucket");
        assert!(refill_per_s >= 0.0, "negative refill");
        Self { capacity, refill_per_s, tokens: capacity, last: now }
    }

    fn refill(&mut self, now: Instant) {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * self.refill_per_s).min(self.capacity);
        self.last = now;
    }

    /// Whether one token is available at `now` (refills, consumes
    /// nothing).
    pub fn peek(&mut self, now: Instant) -> bool {
        self.refill(now);
        self.tokens >= 1.0
    }

    /// Consume one token if available at `now`.
    pub fn try_take(&mut self, now: Instant) -> bool {
        self.refill(now);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    pub fn available(&self) -> f64 {
        self.tokens
    }
}

/// The server's degradation mode — what the hysteretic [`ModeMachine`]
/// decided, as opposed to the instantaneous [`PressureLevel`]. Ordered:
/// `Normal < Brownout < Shed`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ServeMode {
    /// full service
    Normal,
    /// admit only requests whose *effective* priority clears
    /// [`PressureConfig::brownout_min_priority`]; clamp generation
    /// budgets to [`PressureConfig::brownout_max_tokens`]
    Brownout,
    /// reject every queued request structurally until pressure falls
    Shed,
}

impl ServeMode {
    pub fn name(self) -> &'static str {
        match self {
            ServeMode::Normal => "normal",
            ServeMode::Brownout => "brownout",
            ServeMode::Shed => "shed",
        }
    }

    fn rung(self) -> u8 {
        match self {
            ServeMode::Normal => 0,
            ServeMode::Brownout => 1,
            ServeMode::Shed => 2,
        }
    }

    fn from_rung(r: u8) -> Self {
        match r {
            0 => ServeMode::Normal,
            1 => ServeMode::Brownout,
            _ => ServeMode::Shed,
        }
    }
}

/// Hysteresis thresholds for the mode machine. Enter thresholds must
/// sit strictly above their exits — the gap is what prevents flapping —
/// and a transition additionally waits out `min_dwell` in the current
/// mode.
#[derive(Debug, Clone, Copy)]
pub struct BrownoutPolicy {
    pub enter_brownout: f64,
    pub exit_brownout: f64,
    pub enter_shed: f64,
    pub exit_shed: f64,
    pub min_dwell: Duration,
}

impl Default for BrownoutPolicy {
    fn default() -> Self {
        Self {
            enter_brownout: 0.80,
            exit_brownout: 0.60,
            enter_shed: 0.95,
            exit_shed: 0.75,
            min_dwell: Duration::from_millis(100),
        }
    }
}

impl BrownoutPolicy {
    fn validate(&self) {
        assert!(self.exit_brownout < self.enter_brownout, "brownout hysteresis inverted");
        assert!(self.exit_shed < self.enter_shed, "shed hysteresis inverted");
        assert!(self.enter_brownout <= self.enter_shed, "shed must enter above brownout");
    }
}

/// The hysteretic Normal → Brownout → Shed state machine. Moves at
/// most **one rung per observation**, and only after `min_dwell` in the
/// current mode — so a pressure spike ramps the ladder deterministically
/// and oscillation around a single threshold cannot flap the mode.
#[derive(Debug)]
pub struct ModeMachine {
    policy: BrownoutPolicy,
    mode: ServeMode,
    since: Instant,
}

impl ModeMachine {
    pub fn new(policy: BrownoutPolicy, now: Instant) -> Self {
        policy.validate();
        Self { policy, mode: ServeMode::Normal, since: now }
    }

    pub fn mode(&self) -> ServeMode {
        self.mode
    }

    /// How long the machine has sat in its current mode as of `now`.
    pub fn dwell(&self, now: Instant) -> Duration {
        now.saturating_duration_since(self.since)
    }

    /// Feed one occupancy observation; returns the (possibly updated)
    /// mode. Pure in `(self, occ, now)`.
    pub fn observe(&mut self, occ: f64, now: Instant) -> ServeMode {
        let p = &self.policy;
        let desired = match self.mode {
            ServeMode::Normal => {
                if occ >= p.enter_shed {
                    ServeMode::Shed
                } else if occ >= p.enter_brownout {
                    ServeMode::Brownout
                } else {
                    ServeMode::Normal
                }
            }
            ServeMode::Brownout => {
                if occ >= p.enter_shed {
                    ServeMode::Shed
                } else if occ < p.exit_brownout {
                    ServeMode::Normal
                } else {
                    ServeMode::Brownout
                }
            }
            // recovery is one rung at a time: Shed can only step down
            // to Brownout, never jump to Normal
            ServeMode::Shed => {
                if occ < p.exit_shed {
                    ServeMode::Brownout
                } else {
                    ServeMode::Shed
                }
            }
        };
        if desired != self.mode && self.dwell(now) >= p.min_dwell {
            let cur = self.mode.rung();
            let next = if desired.rung() > cur { cur + 1 } else { cur - 1 };
            self.mode = ServeMode::from_rung(next);
            self.since = now;
        }
        self.mode
    }
}

/// Per-tenant admission policy: token-bucket rate plus a hard KV-block
/// quota and a DRR weight.
#[derive(Debug, Clone, Copy)]
pub struct TenantPolicy {
    /// token-bucket burst capacity (requests)
    pub rate_capacity: f64,
    /// sustained admission rate (requests per second)
    pub rate_per_s: f64,
    /// hard cap on this tenant's *reserved* KV blocks (worst-case
    /// reservations of its live sequences)
    pub max_kv_blocks: usize,
    /// deficit-round-robin weight (relative share of admission
    /// bandwidth)
    pub weight: u32,
}

impl Default for TenantPolicy {
    fn default() -> Self {
        Self {
            rate_capacity: 16.0,
            rate_per_s: 64.0,
            max_kv_blocks: usize::MAX,
            weight: 1,
        }
    }
}

/// Everything the governor needs to run. `Default` is a sane serving
/// posture; the sim tests pin every field explicitly.
#[derive(Debug, Clone)]
pub struct PressureConfig {
    pub watermarks: Watermarks,
    pub brownout: BrownoutPolicy,
    /// policy applied to tenants without an explicit override
    pub tenant: TenantPolicy,
    /// DRR quantum in KV blocks credited per tenant per admission round
    pub quantum: usize,
    /// queueing time that raises effective priority by one
    pub aging_interval: Duration,
    /// cap on the aging bonus (levels)
    pub aging_cap: u32,
    /// bound on the waiting queue — the lowest-effective-priority tail
    /// beyond it is shed with structured rejections
    pub max_waiting: usize,
    /// Brownout admission gate on *effective* priority (aging lets
    /// patient low-priority requests through eventually)
    pub brownout_min_priority: u32,
    /// Brownout clamp on `max_new_tokens` at admission
    pub brownout_max_tokens: usize,
    /// opt-in: cancel *running* sequences whose deadline passed
    /// (`FinishReason::Cancelled`, KV freed through the normal release
    /// path). Default off — PR 6's "never kill running" stands.
    pub cancel_past_deadline: bool,
}

impl Default for PressureConfig {
    fn default() -> Self {
        Self {
            watermarks: Watermarks::default(),
            brownout: BrownoutPolicy::default(),
            tenant: TenantPolicy::default(),
            quantum: 4,
            aging_interval: Duration::from_millis(50),
            aging_cap: 8,
            max_waiting: 64,
            brownout_min_priority: 1,
            brownout_max_tokens: 16,
            cancel_past_deadline: false,
        }
    }
}

/// Live per-tenant accounting: rate bucket, reserved blocks, DRR
/// deficit.
#[derive(Debug)]
pub struct TenantState {
    pub policy: TenantPolicy,
    pub bucket: TokenBucket,
    /// worst-case blocks reserved by this tenant's live sequences
    pub reserved_blocks: usize,
    /// DRR credit (blocks) — charged per round, spent per admission
    pub deficit: usize,
}

/// Per-tenant observability counters.
#[derive(Debug, Clone, Default)]
pub struct TenantCounters {
    pub submitted: u64,
    pub admitted: u64,
    /// structured rejections while waiting (queue bound or Shed mode)
    pub shed: u64,
    pub completed: u64,
    pub cancelled: u64,
    /// admission turns skipped because the rate bucket was dry
    pub rate_deferred: u64,
    /// admission turns skipped because the KV quota was full
    pub quota_deferred: u64,
    pub peak_reserved_blocks: usize,
    /// arrival → admission queueing delay
    pub wait: LatencyHistogram,
}

/// The governor's observable state: occupancy, cascade counters,
/// mode dwell times, per-tenant histograms. Cloned into
/// [`crate::coordinator::supervisor::HealthReport`] and rendered by
/// `serve --health-log` / `kv-sim --overload`.
#[derive(Debug, Clone, Default)]
pub struct PressureMetrics {
    pub occupancy: f64,
    pub peak_occupancy: f64,
    /// proactive reclaim sweeps at the High watermark
    pub reclaim_calls: u64,
    /// blocks freed by those sweeps (idle trie blocks compressed)
    pub reclaimed_blocks: u64,
    /// waiting requests rejected structurally (queue bound + Shed)
    pub shed_waiting: u64,
    /// running sequences cancelled past their deadline (opt-in)
    pub cancelled: u64,
    pub rate_deferred: u64,
    pub quota_deferred: u64,
    /// admission turns blocked by the Brownout priority gate
    pub brownout_deferred: u64,
    /// generation budgets clamped at admission in Brownout
    pub clamped_budgets: u64,
    pub mode_changes: u64,
    /// cumulative dwell per mode, indexed by `ServeMode::rung`
    pub time_in_mode: [Duration; 3],
    pub tenants: BTreeMap<TenantId, TenantCounters>,
}

impl PressureMetrics {
    pub fn tenant(&mut self, t: TenantId) -> &mut TenantCounters {
        self.tenants.entry(t).or_default()
    }

    /// One line per concern — the health-log / kv-sim rendering.
    pub fn render(&self, level: PressureLevel, mode: ServeMode) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "pressure: occupancy {:.3} (peak {:.3}) level {} mode {}\n",
            self.occupancy,
            self.peak_occupancy,
            level.name(),
            mode.name()
        ));
        out.push_str(&format!(
            "cascade: reclaimed {} blocks in {} sweeps, shed {} waiting, cancelled {}, \
             deferred rate/quota/brownout {}/{}/{}, clamped {}\n",
            self.reclaimed_blocks,
            self.reclaim_calls,
            self.shed_waiting,
            self.cancelled,
            self.rate_deferred,
            self.quota_deferred,
            self.brownout_deferred,
            self.clamped_budgets,
        ));
        out.push_str(&format!(
            "modes: {} changes; dwell normal {:.3}s brownout {:.3}s shed {:.3}s\n",
            self.mode_changes,
            self.time_in_mode[0].as_secs_f64(),
            self.time_in_mode[1].as_secs_f64(),
            self.time_in_mode[2].as_secs_f64(),
        ));
        for (t, c) in &self.tenants {
            out.push_str(&format!(
                "tenant {t}: submitted {} admitted {} shed {} completed {} cancelled {} \
                 wait-mean {:.4}s peak-reserved {}\n",
                c.submitted,
                c.admitted,
                c.shed,
                c.completed,
                c.cancelled,
                c.wait.mean_s(),
                c.peak_reserved_blocks,
            ));
        }
        out
    }
}

/// The scheduler-side overload governor. Owns the mode machine, the
/// per-tenant buckets/quotas/deficits, and the pressure metrics; the
/// [`super::policy::ContinuousScheduler`] drives it once per step.
/// Every method is pure in its arguments — no internal clock reads.
pub struct PressureGovernor {
    cfg: PressureConfig,
    machine: ModeMachine,
    level: PressureLevel,
    tenants: BTreeMap<TenantId, TenantState>,
    /// starting offset into the sorted per-round tenant list; advanced
    /// once per admission round so no tenant permanently goes first
    rr_cursor: u64,
    last_observe: Instant,
    pub metrics: PressureMetrics,
    /// shared flight recorder: mode transitions land in its ring, and
    /// entering Shed arms the overload postmortem
    recorder: Option<Arc<FlightRecorder>>,
}

impl PressureGovernor {
    pub fn new(cfg: PressureConfig, now: Instant) -> Self {
        cfg.brownout.validate();
        assert!(cfg.watermarks.high <= cfg.watermarks.critical, "watermarks inverted");
        assert!(cfg.quantum > 0, "zero DRR quantum");
        assert!(cfg.aging_interval > Duration::ZERO, "zero aging interval");
        Self {
            machine: ModeMachine::new(cfg.brownout, now),
            cfg,
            level: PressureLevel::Low,
            tenants: BTreeMap::new(),
            rr_cursor: 0,
            last_observe: now,
            metrics: PressureMetrics::default(),
            recorder: None,
        }
    }

    /// Attach the shared flight recorder (the scheduler hands its own
    /// down via `with_recorder` / `with_governor`, in either order).
    pub fn set_recorder(&mut self, recorder: Arc<FlightRecorder>) {
        self.recorder = Some(recorder);
    }

    pub fn config(&self) -> &PressureConfig {
        &self.cfg
    }

    pub fn level(&self) -> PressureLevel {
        self.level
    }

    pub fn mode(&self) -> ServeMode {
        self.machine.mode()
    }

    /// Override the policy for one tenant (noisy-neighbor containment).
    pub fn set_tenant_policy(&mut self, t: TenantId, policy: TenantPolicy, now: Instant) {
        let st = self.tenant_entry(t, now);
        st.policy = policy;
        st.bucket = TokenBucket::new(policy.rate_capacity, policy.rate_per_s, now);
    }

    fn tenant_entry(&mut self, t: TenantId, now: Instant) -> &mut TenantState {
        let default = self.cfg.tenant;
        self.tenants.entry(t).or_insert_with(|| TenantState {
            policy: default,
            bucket: TokenBucket::new(default.rate_capacity, default.rate_per_s, now),
            reserved_blocks: 0,
            deficit: 0,
        })
    }

    /// Feed one pool observation: classifies the pressure level, ticks
    /// the mode machine, accumulates time-in-mode. Call exactly once
    /// per scheduler step, before any cascade action.
    pub fn observe(&mut self, used: usize, total: usize, now: Instant) -> (PressureLevel, ServeMode) {
        let dt = now.saturating_duration_since(self.last_observe);
        self.metrics.time_in_mode[self.machine.mode().rung() as usize] += dt;
        self.last_observe = now;

        let occ = occupancy(used, total);
        self.metrics.occupancy = occ;
        if occ > self.metrics.peak_occupancy {
            self.metrics.peak_occupancy = occ;
        }
        self.level = self.cfg.watermarks.classify(used, total);
        let before = self.machine.mode();
        let mode = self.machine.observe(occ, now);
        if mode != before {
            self.metrics.mode_changes += 1;
            if let Some(rc) = &self.recorder {
                rc.record(FlightEvent::ModeTransition {
                    from: before,
                    to: mode,
                    level: self.level,
                    occupancy: occ,
                    used_blocks: used,
                    total_blocks: total,
                });
                if mode == ServeMode::Shed {
                    // arm the overload postmortem: the scheduler's
                    // end-of-step safe point flushes it *after* the
                    // shed drain this transition causes has been
                    // recorded, so the dump shows cause and effect
                    rc.trigger(DumpReason::ShedEntry);
                }
            }
        }
        (self.level, mode)
    }

    /// Re-classify the level after a cascade action changed the pool
    /// (reclaim frees blocks) without ticking the mode machine.
    pub fn reclassify(&mut self, used: usize, total: usize) -> PressureLevel {
        self.level = self.cfg.watermarks.classify(used, total);
        self.metrics.occupancy = occupancy(used, total);
        self.level
    }

    /// Free-block target that returns occupancy to the high watermark:
    /// the governor reclaims until `free >= total - floor(high*total)`.
    pub fn reclaim_target(&self, total: usize) -> usize {
        total - (self.cfg.watermarks.high * total as f64).floor() as usize
    }

    pub fn note_reclaim(&mut self, freed: usize) {
        self.metrics.reclaim_calls += 1;
        self.metrics.reclaimed_blocks += freed as u64;
    }

    /// Effective priority = static priority + one level per
    /// `aging_interval` queued, capped — the starvation-freedom lever.
    /// Integer nanosecond arithmetic, so `SimClock` replays (and the
    /// Python port) agree bit-for-bit.
    pub fn effective_priority(&self, priority: u8, arrived: Instant, now: Instant) -> u32 {
        let waited = now.saturating_duration_since(arrived).as_nanos();
        let bonus = (waited / self.cfg.aging_interval.as_nanos()).min(self.cfg.aging_cap as u128);
        priority as u32 + bonus as u32
    }

    /// Whether `need` more reserved blocks fit tenant `t`'s quota.
    pub fn quota_allows(&mut self, t: TenantId, need: usize, now: Instant) -> bool {
        let st = self.tenant_entry(t, now);
        st.reserved_blocks.saturating_add(need) <= st.policy.max_kv_blocks
    }

    /// One token available in tenant `t`'s rate bucket at `now`?
    pub fn rate_peek(&mut self, t: TenantId, now: Instant) -> bool {
        self.tenant_entry(t, now).bucket.peek(now)
    }

    /// Commit an admission: consume a rate token, reserve `blocks`,
    /// spend DRR deficit, record the queueing delay.
    pub fn commit_admission(
        &mut self,
        t: TenantId,
        blocks: usize,
        arrived: Instant,
        now: Instant,
    ) {
        let st = self.tenant_entry(t, now);
        let took = st.bucket.try_take(now);
        debug_assert!(took, "commit after rate_peek");
        st.reserved_blocks += blocks;
        st.deficit = st.deficit.saturating_sub(blocks);
        let peak = st.reserved_blocks;
        let c = self.metrics.tenant(t);
        c.admitted += 1;
        c.peak_reserved_blocks = c.peak_reserved_blocks.max(peak);
        c.wait.record(now.saturating_duration_since(arrived).as_secs_f64());
    }

    /// Release a finished/cancelled sequence's reservation.
    pub fn release_reservation(&mut self, t: TenantId, blocks: usize, now: Instant) {
        let st = self.tenant_entry(t, now);
        st.reserved_blocks = st.reserved_blocks.saturating_sub(blocks);
    }

    pub fn reserved_blocks(&self, t: TenantId) -> usize {
        self.tenants.get(&t).map(|s| s.reserved_blocks).unwrap_or(0)
    }

    /// Charge one round's DRR credit (`weight × quantum` blocks).
    pub fn charge_deficit(&mut self, t: TenantId, now: Instant) {
        let quantum = self.cfg.quantum;
        let st = self.tenant_entry(t, now);
        st.deficit = st.deficit.saturating_add(st.policy.weight as usize * quantum);
    }

    /// Classic DRR: a tenant with nothing queued forfeits its credit.
    pub fn reset_deficit(&mut self, t: TenantId) {
        if let Some(st) = self.tenants.get_mut(&t) {
            st.deficit = 0;
        }
    }

    pub fn deficit(&self, t: TenantId) -> usize {
        self.tenants.get(&t).map(|s| s.deficit).unwrap_or(0)
    }

    /// Tenants with live state, ascending id order.
    pub fn tenant_ids(&self) -> Vec<TenantId> {
        self.tenants.keys().copied().collect()
    }

    /// Where this round's tenant iteration starts (rotates per round).
    pub fn rr_start(&self, n_tenants: usize) -> usize {
        if n_tenants == 0 {
            0
        } else {
            (self.rr_cursor % n_tenants as u64) as usize
        }
    }

    pub fn advance_rr(&mut self) {
        self.rr_cursor = self.rr_cursor.wrapping_add(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn watermarks_classify_with_inclusive_boundaries() {
        let w = Watermarks { high: 0.5, critical: 0.75 };
        // 100-block pool: 49 → Low, 50 → High (>=), 74 → High, 75 → Critical
        assert_eq!(w.classify(49, 100), PressureLevel::Low);
        assert_eq!(w.classify(50, 100), PressureLevel::High);
        assert_eq!(w.classify(74, 100), PressureLevel::High);
        assert_eq!(w.classify(75, 100), PressureLevel::Critical);
        assert_eq!(w.classify(0, 0), PressureLevel::Low, "empty pool is unpressured");
        assert!(PressureLevel::Low < PressureLevel::High);
        assert!(PressureLevel::High < PressureLevel::Critical);
    }

    #[test]
    fn token_bucket_refills_deterministically() {
        let now = t0();
        let mut b = TokenBucket::new(2.0, 10.0, now);
        assert!(b.try_take(now));
        assert!(b.try_take(now));
        assert!(!b.try_take(now), "burst capacity exhausted");
        // 100ms at 10/s = exactly one token
        let later = now + Duration::from_millis(100);
        assert!(b.peek(later));
        assert!(b.try_take(later));
        assert!(!b.try_take(later));
        // refill caps at capacity
        let much_later = later + Duration::from_secs(60);
        b.refill(much_later);
        assert_eq!(b.available(), 2.0);
    }

    #[test]
    fn mode_machine_ramps_one_rung_per_observation() {
        let now = t0();
        let p = BrownoutPolicy {
            enter_brownout: 0.8,
            exit_brownout: 0.6,
            enter_shed: 0.95,
            exit_shed: 0.75,
            min_dwell: Duration::from_millis(10),
        };
        let mut m = ModeMachine::new(p, now);
        assert_eq!(m.mode(), ServeMode::Normal);
        // saturated pool: wants Shed, but steps through Brownout first
        let t1 = now + Duration::from_millis(10);
        assert_eq!(m.observe(1.0, t1), ServeMode::Brownout);
        // dwell not yet served at t1 → stays Brownout
        assert_eq!(m.observe(1.0, t1), ServeMode::Brownout);
        let t2 = t1 + Duration::from_millis(10);
        assert_eq!(m.observe(1.0, t2), ServeMode::Shed);
        // recovery also steps one rung: Shed → Brownout → Normal
        let t3 = t2 + Duration::from_millis(10);
        assert_eq!(m.observe(0.0, t3), ServeMode::Brownout);
        let t4 = t3 + Duration::from_millis(10);
        assert_eq!(m.observe(0.0, t4), ServeMode::Normal);
    }

    #[test]
    fn mode_machine_hysteresis_never_flaps() {
        let now = t0();
        let p = BrownoutPolicy::default(); // enter 0.80 / exit 0.60
        let mut m = ModeMachine::new(p, now);
        let t1 = now + Duration::from_secs(1);
        assert_eq!(m.observe(0.85, t1), ServeMode::Brownout);
        // oscillating in the hysteresis band (0.60..0.80) changes nothing,
        // no matter how much time passes
        for i in 2..50 {
            let t = now + Duration::from_secs(i);
            let occ = if i % 2 == 0 { 0.79 } else { 0.61 };
            assert_eq!(m.observe(occ, t), ServeMode::Brownout, "flapped at i={i}");
        }
        // only falling through the exit threshold recovers
        let t = now + Duration::from_secs(60);
        assert_eq!(m.observe(0.59, t), ServeMode::Normal);
    }

    #[test]
    fn mode_machine_dwell_blocks_early_transitions() {
        let now = t0();
        let p = BrownoutPolicy {
            min_dwell: Duration::from_millis(100),
            ..BrownoutPolicy::default()
        };
        let mut m = ModeMachine::new(p, now);
        // pressure spikes immediately, but dwell in Normal not served
        assert_eq!(m.observe(0.99, now + Duration::from_millis(50)), ServeMode::Normal);
        // exactly at the dwell boundary (>=): transition fires
        assert_eq!(m.observe(0.99, now + Duration::from_millis(100)), ServeMode::Brownout);
    }

    #[test]
    fn effective_priority_ages_and_caps() {
        let now = t0();
        let g = PressureGovernor::new(
            PressureConfig {
                aging_interval: Duration::from_millis(50),
                aging_cap: 3,
                ..PressureConfig::default()
            },
            now,
        );
        let arrived = now;
        assert_eq!(g.effective_priority(2, arrived, now), 2);
        // one tick under the interval: no bonus
        assert_eq!(
            g.effective_priority(2, arrived, now + Duration::from_millis(50) - Duration::from_nanos(1)),
            2
        );
        assert_eq!(g.effective_priority(2, arrived, now + Duration::from_millis(50)), 3);
        assert_eq!(g.effective_priority(2, arrived, now + Duration::from_millis(149)), 4);
        // capped at +3 no matter how stale
        assert_eq!(g.effective_priority(2, arrived, now + Duration::from_secs(60)), 5);
        // a zero-priority request eventually outranks a fresh priority-2
        assert!(g.effective_priority(0, arrived, now + Duration::from_millis(150)) > 2);
    }

    #[test]
    fn quota_reserve_release_roundtrip() {
        let now = t0();
        let mut g = PressureGovernor::new(PressureConfig::default(), now);
        g.set_tenant_policy(
            7,
            TenantPolicy { max_kv_blocks: 10, ..TenantPolicy::default() },
            now,
        );
        assert!(g.quota_allows(7, 10, now));
        assert!(!g.quota_allows(7, 11, now));
        g.commit_admission(7, 6, now, now);
        assert_eq!(g.reserved_blocks(7), 6);
        assert!(g.quota_allows(7, 4, now));
        assert!(!g.quota_allows(7, 5, now));
        g.release_reservation(7, 6, now);
        assert_eq!(g.reserved_blocks(7), 0);
        assert_eq!(g.metrics.tenant(7).peak_reserved_blocks, 6);
    }

    #[test]
    fn observe_accumulates_time_in_mode() {
        let now = t0();
        let mut g = PressureGovernor::new(
            PressureConfig {
                brownout: BrownoutPolicy {
                    min_dwell: Duration::ZERO,
                    ..BrownoutPolicy::default()
                },
                ..PressureConfig::default()
            },
            now,
        );
        let (level, mode) = g.observe(90, 100, now + Duration::from_millis(30));
        assert_eq!(level, PressureLevel::Critical);
        assert_eq!(mode, ServeMode::Brownout);
        assert_eq!(g.metrics.mode_changes, 1);
        // the 30ms before the flip were spent Normal
        assert_eq!(g.metrics.time_in_mode[0], Duration::from_millis(30));
        // 0.96 crosses enter_shed; the 20ms since the flip were Brownout
        g.observe(96, 100, now + Duration::from_millis(50));
        assert_eq!(g.metrics.time_in_mode[1], Duration::from_millis(20));
        assert_eq!(g.mode(), ServeMode::Shed);
        // reclassify adjusts the level without ticking the machine
        assert_eq!(g.reclassify(10, 100), PressureLevel::Low);
        assert_eq!(g.mode(), ServeMode::Shed, "reclassify leaves the mode machine alone");
    }

    #[test]
    fn drr_deficit_charges_by_weight_and_resets() {
        let now = t0();
        let mut g = PressureGovernor::new(
            PressureConfig { quantum: 4, ..PressureConfig::default() },
            now,
        );
        g.set_tenant_policy(1, TenantPolicy { weight: 3, ..TenantPolicy::default() }, now);
        g.charge_deficit(0, now);
        g.charge_deficit(1, now);
        assert_eq!(g.deficit(0), 4);
        assert_eq!(g.deficit(1), 12, "weight multiplies the quantum");
        g.charge_deficit(1, now);
        assert_eq!(g.deficit(1), 24);
        g.reset_deficit(1);
        assert_eq!(g.deficit(1), 0);
        // round-robin start rotates
        assert_eq!(g.rr_start(3), 0);
        g.advance_rr();
        assert_eq!(g.rr_start(3), 1);
        g.advance_rr();
        g.advance_rr();
        assert_eq!(g.rr_start(3), 0);
    }

    #[test]
    fn reclaim_target_restores_high_watermark_headroom() {
        let now = t0();
        let g = PressureGovernor::new(
            PressureConfig {
                watermarks: Watermarks { high: 0.70, critical: 0.90 },
                ..PressureConfig::default()
            },
            now,
        );
        // 100 blocks at high=0.70 → keep at least 30 free
        assert_eq!(g.reclaim_target(100), 30);
        // 12 blocks: floor(0.7*12)=8 used → 4 free
        assert_eq!(g.reclaim_target(12), 4);
    }
}
