//! Radix prefix index over the paged KV cache — the structure behind
//! multi-tenant prompt reuse.
//!
//! ## Shape
//!
//! A trie keyed on *token-block boundaries*: every node is exactly one
//! KV block's worth of tokens (`block_tokens` of them), and a root→node
//! path spells out a prompt prefix. Matching a prompt walks full blocks
//! top-down (first inserted child wins — deterministic), then checks
//! whether the sub-block remainder is a prefix of one child (the
//! partial-tail link that makes copy-on-write forks real work, not a
//! theoretical case).
//!
//! ## Tiers
//!
//! Each node's block lives in one of two states and can be dropped:
//!
//! * **Hot** — resident in a pool block; the trie holds one refcount on
//!   it, sharers hold more. A hit links it for free.
//! * **Compressed** — the block's bytes were evicted through the codec
//!   registry (`select_codec_with(kv_evict_params())`, the §3.2 probe)
//!   into the bounded cold tier; a hit restores bit-identically via
//!   `decode_into_disjoint`. Reclaim compresses the LRU hot node whose
//!   block nobody else references.
//! * **Dropped** — when the cold tier exceeds its byte budget, the LRU
//!   *unpinned compressed leaf* is forgotten entirely (a later request
//!   re-prefills it). Pinned nodes — ones an evicted sequence still
//!   references — may be compressed but never dropped, so preempted
//!   sharers always restore.
//!
//! The index itself owns no pool blocks and does no allocation; the
//! [`crate::scheduler::kv_cache::KvCacheManager`] drives every state
//! transition and keeps refcounts/bytes honest (cross-checked by its
//! extended `leak_check`).

use crate::codec::codecs::CompressedTensor;

/// Cold-tier budget for the prefix cache.
#[derive(Debug, Clone, Copy)]
pub struct PrefixCacheConfig {
    /// stored-byte bound on the compressed tier; beyond it, LRU
    /// unpinned compressed leaves are dropped
    pub max_compressed_bytes: usize,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        Self {
            max_compressed_bytes: 256 * 1024,
        }
    }
}

/// Prefix-cache counters the metrics/benches report.
#[derive(Debug, Clone, Default)]
pub struct PrefixStats {
    /// prompts matched against the index at admission
    pub lookups: u64,
    /// lookups that matched at least one token
    pub hits: u64,
    /// prefill positions skipped because their blocks were linked
    pub matched_tokens: u64,
    /// trie nodes created from freshly prefilled blocks
    pub inserted_nodes: u64,
    /// private blocks freed because an identical trie block existed
    pub dedup_blocks: u64,
    /// compressed nodes re-homed onto a sharer's identical private block
    pub adopted_blocks: u64,
    /// private copies made when a write landed in a shared block
    pub cow_forks: u64,
    /// hot→compressed transitions (reclaim)
    pub compressions: u64,
    /// compressed→hot transitions (hit on a cold prefix)
    pub restores: u64,
    /// evicted sharers that re-linked a still-hot node on resume
    pub relinks: u64,
    /// compressed nodes dropped by the byte budget
    pub drops: u64,
    /// current / peak cold-tier occupancy
    pub compressed_bytes: usize,
    pub peak_compressed_bytes: usize,
}

impl PrefixStats {
    pub(crate) fn add_compressed(&mut self, bytes: usize) {
        self.compressed_bytes += bytes;
        self.peak_compressed_bytes = self.peak_compressed_bytes.max(self.compressed_bytes);
    }

    pub(crate) fn sub_compressed(&mut self, bytes: usize) {
        debug_assert!(self.compressed_bytes >= bytes);
        self.compressed_bytes -= bytes;
    }
}

/// Point-in-time tier occupancy (the "tier census" kv-sim prints).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierCensus {
    pub hot_nodes: usize,
    pub compressed_nodes: usize,
    pub compressed_bytes: usize,
    /// nodes an evicted sequence still depends on (never droppable)
    pub pinned_nodes: usize,
}

/// Where a prefix block's bytes live right now.
#[derive(Debug)]
pub(crate) enum NodeState {
    /// resident pool block; the trie holds one refcount on it
    Hot(u32),
    /// codec-registry payload in the bounded cold tier
    Compressed(CompressedTensor),
}

#[derive(Debug)]
pub(crate) struct PrefixNode {
    /// exactly `block_tokens` tokens — one full KV block
    pub tokens: Box<[i32]>,
    pub parent: Option<u32>,
    /// insertion order; matching scans in order → deterministic
    pub children: Vec<u32>,
    pub state: NodeState,
    /// evicted sequences holding a `Shared` slot on this node. A pinned
    /// node may be compressed, never dropped.
    pub pins: u32,
    /// logical LRU stamp (bumped on every match/insert touching it)
    pub last_hit: u64,
}

/// Result of matching a prompt against the index.
#[derive(Debug, Default)]
pub(crate) struct PrefixMatch {
    /// fully matched block nodes, root-down
    pub chain: Vec<u32>,
    /// node whose block *starts with* the sub-block prompt remainder
    /// (linking it skips the remainder's prefill; the first write into
    /// it CoW-forks)
    pub tail: Option<u32>,
    /// prompt positions covered by `chain` + `tail`
    pub matched_tokens: usize,
}

/// The radix index: a slab of nodes (tombstoned — ids stay stable) with
/// explicit roots. Pure structure; the manager owns all block/byte
/// state transitions.
#[derive(Debug)]
pub(crate) struct PrefixIndex {
    pub cfg: PrefixCacheConfig,
    nodes: Vec<Option<PrefixNode>>,
    roots: Vec<u32>,
    tick: u64,
    pub stats: PrefixStats,
}

impl PrefixIndex {
    pub fn new(cfg: PrefixCacheConfig) -> Self {
        Self {
            cfg,
            nodes: Vec::new(),
            roots: Vec::new(),
            tick: 0,
            stats: PrefixStats::default(),
        }
    }

    pub fn node(&self, id: u32) -> &PrefixNode {
        self.nodes[id as usize].as_ref().expect("live node")
    }

    pub fn node_mut(&mut self, id: u32) -> &mut PrefixNode {
        self.nodes[id as usize].as_mut().expect("live node")
    }

    /// Bump `id`'s LRU stamp.
    pub fn touch(&mut self, id: u32) {
        self.tick += 1;
        let t = self.tick;
        self.node_mut(id).last_hit = t;
    }

    /// Live `(id, node)` pairs in id order (deterministic scans).
    pub fn iter(&self) -> impl Iterator<Item = (u32, &PrefixNode)> {
        self.nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| n.as_ref().map(|n| (i as u32, n)))
    }

    fn children_of(&self, parent: Option<u32>) -> &[u32] {
        match parent {
            Some(p) => &self.node(p).children,
            None => &self.roots,
        }
    }

    /// First child of `parent` whose tokens equal `block` exactly.
    pub fn child_eq(&self, parent: Option<u32>, block: &[i32]) -> Option<u32> {
        self.children_of(parent)
            .iter()
            .copied()
            .find(|&c| &*self.node(c).tokens == block)
    }

    /// First child of `parent` whose tokens *start with* `rem`.
    fn child_starting_with(&self, parent: Option<u32>, rem: &[i32]) -> Option<u32> {
        self.children_of(parent)
            .iter()
            .copied()
            .find(|&c| self.node(c).tokens.starts_with(rem))
    }

    /// Pure match of `prompt` (block granularity `bt`): longest chain of
    /// full blocks, then an optional partial-tail child. Never covers
    /// the whole of `prompt` *and* a full tail block — `matched_tokens`
    /// ≤ `prompt.len()` always.
    pub fn lookup(&self, prompt: &[i32], bt: usize) -> PrefixMatch {
        let mut m = PrefixMatch::default();
        let mut parent = None;
        while (m.chain.len() + 1) * bt <= prompt.len() {
            let i = m.chain.len();
            let block = &prompt[i * bt..(i + 1) * bt];
            match self.child_eq(parent, block) {
                Some(c) => {
                    m.chain.push(c);
                    parent = Some(c);
                }
                None => break,
            }
        }
        m.matched_tokens = m.chain.len() * bt;
        // a divergence inside a full block ends the match (positions
        // after it differ); only a *shorter-than-a-block* remainder can
        // ride a child's block
        let rem = &prompt[m.chain.len() * bt..];
        if !rem.is_empty() && rem.len() < bt {
            if let Some(c) = self.child_starting_with(parent, rem) {
                m.tail = Some(c);
                m.matched_tokens += rem.len();
            }
        }
        m
    }

    /// Insert a new Hot node for `tokens` under `parent`. The caller
    /// has already checked no equal child exists and holds the trie's
    /// refcount on `block`.
    pub fn insert(&mut self, parent: Option<u32>, tokens: &[i32], block: u32) -> u32 {
        let id = self.nodes.len() as u32;
        self.nodes.push(Some(PrefixNode {
            tokens: tokens.into(),
            parent,
            children: Vec::new(),
            state: NodeState::Hot(block),
            pins: 0,
            last_hit: 0,
        }));
        match parent {
            Some(p) => self.node_mut(p).children.push(id),
            None => self.roots.push(id),
        }
        self.stats.inserted_nodes += 1;
        self.touch(id);
        id
    }

    /// Detach and forget `id` (must be a leaf). Returns its state.
    pub fn remove(&mut self, id: u32) -> NodeState {
        let node = self.nodes[id as usize].take().expect("live node");
        assert!(node.children.is_empty(), "only leaves are removable");
        match node.parent {
            Some(p) => self.node_mut(p).children.retain(|&c| c != id),
            None => self.roots.retain(|&c| c != id),
        }
        node.state
    }

    /// LRU hot node passing `keep` (used by reclaim: `keep` filters to
    /// blocks nobody but the trie references). Ties break on node id.
    pub fn lru_hot(&self, keep: impl Fn(u32, u32) -> bool) -> Option<u32> {
        self.iter()
            .filter_map(|(id, n)| match n.state {
                NodeState::Hot(b) if keep(id, b) => Some((n.last_hit, id)),
                _ => None,
            })
            .min()
            .map(|(_, id)| id)
    }

    /// LRU droppable node: compressed, unpinned, leaf. Interior nodes
    /// survive until their subtree drains (dropping one would strand
    /// descendants whose match path runs through it).
    pub fn lru_droppable(&self) -> Option<u32> {
        self.iter()
            .filter_map(|(id, n)| match n.state {
                NodeState::Compressed(_) if n.pins == 0 && n.children.is_empty() => {
                    Some((n.last_hit, id))
                }
                _ => None,
            })
            .min()
            .map(|(_, id)| id)
    }

    pub fn census(&self) -> TierCensus {
        let mut c = TierCensus {
            compressed_bytes: self.stats.compressed_bytes,
            ..TierCensus::default()
        };
        for (_, n) in self.iter() {
            match n.state {
                NodeState::Hot(_) => c.hot_nodes += 1,
                NodeState::Compressed(_) => c.compressed_nodes += 1,
            }
            if n.pins > 0 {
                c.pinned_nodes += 1;
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::codecs::{compress_auto, CompressedTensor};
    use crate::codec::Fp8Format;
    use crate::scheduler::kv_cache::kv_evict_params;

    fn compressed(bytes: usize) -> CompressedTensor {
        compress_auto(&vec![0x38u8; bytes], Fp8Format::E4M3, kv_evict_params())
    }

    #[test]
    fn lookup_walks_full_blocks_then_partial_tail() {
        let mut ix = PrefixIndex::new(PrefixCacheConfig::default());
        let a = ix.insert(None, &[1, 2, 3, 4], 0);
        let b = ix.insert(Some(a), &[5, 6, 7, 8], 1);
        ix.insert(None, &[9, 9, 9, 9], 2);

        let m = ix.lookup(&[1, 2, 3, 4, 5, 6, 7, 8, 20], 4);
        assert_eq!(m.chain, vec![a, b]);
        assert_eq!(m.tail, None);
        assert_eq!(m.matched_tokens, 8);

        // sub-block remainder rides a child block
        let m = ix.lookup(&[1, 2, 3, 4, 5, 6], 4);
        assert_eq!(m.chain, vec![a]);
        assert_eq!(m.tail, Some(b));
        assert_eq!(m.matched_tokens, 6);

        // divergence inside a full block matches nothing past it
        let m = ix.lookup(&[1, 2, 3, 4, 5, 6, 99, 8], 4);
        assert_eq!(m.chain, vec![a]);
        assert_eq!(m.tail, None, "mid-block divergence cannot share");
        assert_eq!(m.matched_tokens, 4);

        let m = ix.lookup(&[42, 2, 3, 4], 4);
        assert!(m.chain.is_empty() && m.tail.is_none() && m.matched_tokens == 0);
    }

    #[test]
    fn match_order_is_first_inserted_deterministic() {
        let mut ix = PrefixIndex::new(PrefixCacheConfig::default());
        let a = ix.insert(None, &[1, 2], 0);
        ix.insert(None, &[1, 3], 1);
        // partial remainder [1] prefixes both children — first wins
        let m = ix.lookup(&[1], 2);
        assert_eq!(m.tail, Some(a));
    }

    #[test]
    fn lru_prefers_oldest_and_respects_filters() {
        let mut ix = PrefixIndex::new(PrefixCacheConfig::default());
        let a = ix.insert(None, &[1, 2], 10);
        let b = ix.insert(Some(a), &[3, 4], 11);
        let c = ix.insert(None, &[5, 6], 12);
        ix.touch(a); // a is now newest
        assert_eq!(ix.lru_hot(|_, _| true), Some(b));
        assert_eq!(ix.lru_hot(|id, _| id != b), Some(c));

        // droppable: compressed + unpinned + leaf only
        assert_eq!(ix.lru_droppable(), None);
        ix.node_mut(a).state = NodeState::Compressed(compressed(16));
        assert_eq!(ix.lru_droppable(), None, "interior node is not droppable");
        ix.node_mut(b).state = NodeState::Compressed(compressed(16));
        ix.node_mut(b).pins = 1;
        assert_eq!(ix.lru_droppable(), None, "pinned node is not droppable");
        ix.node_mut(b).pins = 0;
        assert_eq!(ix.lru_droppable(), Some(b));
        matches!(ix.remove(b), NodeState::Compressed(_));
        // with b gone, a is a compressed leaf again
        assert_eq!(ix.lru_droppable(), Some(a));
        let m = ix.lookup(&[1, 2, 3, 4], 2);
        assert_eq!(m.chain, vec![a], "removed child no longer matches");
    }

    #[test]
    fn census_counts_tiers_and_pins() {
        let mut ix = PrefixIndex::new(PrefixCacheConfig::default());
        let a = ix.insert(None, &[1, 2], 0);
        ix.insert(Some(a), &[3, 4], 1);
        ix.node_mut(a).state = NodeState::Compressed(compressed(8));
        ix.node_mut(a).pins = 2;
        ix.stats.add_compressed(8);
        let c = ix.census();
        assert_eq!(
            c,
            TierCensus {
                hot_nodes: 1,
                compressed_nodes: 1,
                compressed_bytes: ix.stats.compressed_bytes,
                pinned_nodes: 1
            }
        );
    }
}
