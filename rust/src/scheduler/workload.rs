//! Seeded multi-tenant request generators for the prefix-cache
//! surfaces: `N` tenants each own a fixed **system prompt** (the shared
//! prefix), every request re-sends that prefix followed by a private
//! user suffix. One generator feeds `ecf8 kv-sim --prefix`,
//! `bench_prefix`, and the invariant tests, so all three replay the
//! exact same token streams from a seed — and the Python verify sim
//! (`.claude/skills/verify/sim_prefix.py`) mirrors this module
//! function-for-function.
//!
//! Tokens are drawn per-tenant from a splitmix stream, so a tenant's
//! system prompt is a pure function of `(seed, tenant)` — independent
//! of how many requests are generated or in which order. The first
//! system token is forced onto the weight-like payload lane
//! (see [`super::kv_cache::kv_lane_noise`]) for *even* tenants and the
//! noise lane for *odd* ones, so a multi-tenant run exercises both
//! codecs in the compressed cold tier.

use super::kv_cache::splitmix;
use super::policy::GenRequest;
use std::time::{Duration, Instant};

/// Shape of the seeded shared-prefix workload.
#[derive(Debug, Clone, Copy)]
pub struct SharedPrefixWorkload {
    /// number of distinct system prompts (tenants)
    pub tenants: usize,
    /// tokens in each tenant's shared system prompt
    pub system_tokens: usize,
    /// tokens in each request's private user suffix
    pub user_tokens: usize,
    /// per-request generation budget range (inclusive)
    pub gen_min: usize,
    pub gen_max: usize,
    /// token id range: ids are drawn from `1..=vocab`
    pub vocab: i32,
}

impl Default for SharedPrefixWorkload {
    fn default() -> Self {
        Self {
            tenants: 4,
            system_tokens: 48,
            user_tokens: 12,
            gen_min: 4,
            gen_max: 12,
            vocab: 32_000,
        }
    }
}

/// A tiny deterministic stream over [`splitmix`]: counter-mode, so two
/// streams with different seeds never correlate.
struct Stream {
    seed: u64,
    i: u64,
}

impl Stream {
    fn new(seed: u64) -> Self {
        Self { seed, i: 0 }
    }

    fn next_u64(&mut self) -> u64 {
        self.i += 1;
        splitmix(self.seed ^ self.i.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// uniform in `[1, vocab]`
    fn token(&mut self, vocab: i32) -> i32 {
        (self.next_u64() % vocab as u64) as i32 + 1
    }

    /// uniform in `[lo, hi]`
    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next_u64() % (hi - lo + 1) as u64) as usize
    }
}

impl SharedPrefixWorkload {
    /// Tenant `t`'s system prompt — a pure function of `(seed, t)`.
    pub fn system_prompt(&self, seed: u64, tenant: usize) -> Vec<i32> {
        assert!(self.system_tokens > 0, "empty system prompt");
        let mut s = Stream::new(splitmix(seed) ^ (tenant as u64).wrapping_mul(0x9E37_79B9));
        let mut prompt: Vec<i32> = (0..self.system_tokens)
            .map(|_| s.token(self.vocab))
            .collect();
        // pin the payload lane per tenant parity: even → weight-like
        // (compressible), odd → noise (incompressible), so the cold
        // tier's codec census sees both
        let lane_noise = tenant % 2 == 1;
        let t0 = prompt[0];
        prompt[0] = if lane_noise {
            t0 - t0.rem_euclid(4) + 3
        } else {
            let adjusted = t0 - t0.rem_euclid(4) + 1;
            debug_assert!(adjusted > 0);
            adjusted
        };
        prompt
    }
}

/// Generate `n` requests: request `i` belongs to tenant `i % tenants`,
/// arrives at `start + i * gap`, and carries that tenant's system
/// prompt followed by a private, per-request user suffix. Generation
/// budgets are drawn from `[gen_min, gen_max]` per request.
pub fn shared_prefix_requests(
    w: &SharedPrefixWorkload,
    n: usize,
    seed: u64,
    start: Instant,
    gap: Duration,
) -> Vec<GenRequest> {
    assert!(w.tenants > 0, "need at least one tenant");
    assert!(w.gen_min > 0 && w.gen_min <= w.gen_max, "bad gen range");
    let systems: Vec<Vec<i32>> = (0..w.tenants)
        .map(|t| w.system_prompt(seed, t))
        .collect();
    (0..n)
        .map(|i| {
            let tenant = i % w.tenants;
            let mut s = Stream::new(
                splitmix(seed ^ 0x7265_7175_6573_74) ^ (i as u64).wrapping_mul(0x5851_F42D),
            );
            let mut prompt = systems[tenant].clone();
            prompt.extend((0..w.user_tokens).map(|_| s.token(w.vocab)));
            let budget = s.range(w.gen_min, w.gen_max);
            GenRequest::at(i as u64, prompt, budget, start + gap * i as u32)
                .with_tenant(tenant as u32)
        })
        .collect()
}

/// The adversarial overload mix for the governor gauntlet. Same
/// tenant-interleaved shape as [`shared_prefix_requests`], except
/// tenant `noisy` floods: **all** of its requests arrive at `start` (a
/// thundering herd) at priority 0, each demanding the maximum
/// generation budget — while the well-behaved tenants trickle in at
/// `start + i * gap` with priorities cycling 0..=2 and budgets drawn
/// from the normal range. Deterministic in `(w, n, seed, noisy)`; the
/// `sim_pressure.py` verify port mirrors it line for line.
pub fn overload_requests(
    w: &SharedPrefixWorkload,
    n: usize,
    seed: u64,
    start: Instant,
    gap: Duration,
    noisy: usize,
) -> Vec<GenRequest> {
    assert!(w.tenants > 0, "need at least one tenant");
    assert!(noisy < w.tenants, "noisy tenant out of range");
    assert!(w.gen_min > 0 && w.gen_min <= w.gen_max, "bad gen range");
    let systems: Vec<Vec<i32>> = (0..w.tenants)
        .map(|t| w.system_prompt(seed, t))
        .collect();
    (0..n)
        .map(|i| {
            let tenant = i % w.tenants;
            let mut s = Stream::new(
                splitmix(seed ^ 0x6F76_6572_6C6F_6164) ^ (i as u64).wrapping_mul(0x5851_F42D),
            );
            let mut prompt = systems[tenant].clone();
            prompt.extend((0..w.user_tokens).map(|_| s.token(w.vocab)));
            let (budget, arrived, priority) = if tenant == noisy {
                (w.gen_max, start, 0u8)
            } else {
                (
                    s.range(w.gen_min, w.gen_max),
                    start + gap * i as u32,
                    ((i / w.tenants) % 3) as u8,
                )
            };
            GenRequest::at(i as u64, prompt, budget, arrived)
                .with_priority(priority)
                .with_tenant(tenant as u32)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::kv_cache::kv_lane_noise;

    #[test]
    fn workload_is_deterministic_and_tenant_stable() {
        let w = SharedPrefixWorkload::default();
        let t0 = Instant::now();
        let a = shared_prefix_requests(&w, 12, 7, t0, Duration::from_millis(1));
        let b = shared_prefix_requests(&w, 12, 7, t0, Duration::from_millis(1));
        assert_eq!(a.len(), 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.id, &x.prompt, x.max_new_tokens), (y.id, &y.prompt, y.max_new_tokens));
        }
        // same tenant → same system prefix; different tenants differ
        let sys = w.system_tokens;
        assert_eq!(a[0].prompt[..sys], a[4].prompt[..sys]);
        assert_ne!(a[0].prompt[..sys], a[1].prompt[..sys]);
        // user suffixes are private even within a tenant
        assert_ne!(a[0].prompt[sys..], a[4].prompt[sys..]);
        // a different seed reshuffles everything
        let c = shared_prefix_requests(&w, 12, 8, t0, Duration::from_millis(1));
        assert_ne!(a[0].prompt, c[0].prompt);
    }

    #[test]
    fn tenant_parity_pins_the_payload_lane() {
        let w = SharedPrefixWorkload::default();
        for t in 0..6 {
            let p = w.system_prompt(7, t);
            assert_eq!(p.len(), w.system_tokens);
            assert!(p.iter().all(|&tok| tok >= 1 && tok <= w.vocab));
            assert_eq!(kv_lane_noise(p[0]), t % 2 == 1, "tenant {t}");
        }
    }

    #[test]
    fn overload_mix_floods_exactly_one_tenant() {
        let w = SharedPrefixWorkload::default();
        let t0 = Instant::now();
        let gap = Duration::from_millis(2);
        let reqs = overload_requests(&w, 16, 7, t0, gap, 1);
        let again = overload_requests(&w, 16, 7, t0, gap, 1);
        for (x, y) in reqs.iter().zip(&again) {
            assert_eq!(
                (x.id, &x.prompt, x.max_new_tokens, x.priority, x.tenant),
                (y.id, &y.prompt, y.max_new_tokens, y.priority, y.tenant),
            );
        }
        for r in &reqs {
            assert_eq!(r.tenant as usize, r.id as usize % w.tenants);
            if r.tenant == 1 {
                // the herd: everything at t0, max budget, priority 0
                assert_eq!(r.arrived, t0);
                assert_eq!(r.max_new_tokens, w.gen_max);
                assert_eq!(r.priority, 0);
            } else {
                assert_eq!(r.arrived, t0 + gap * r.id as u32);
                assert!(r.priority <= 2);
                assert!(r.max_new_tokens >= w.gen_min && r.max_new_tokens <= w.gen_max);
            }
        }
    }

    #[test]
    fn arrivals_and_budgets_follow_the_spec() {
        let w = SharedPrefixWorkload {
            gen_min: 3,
            gen_max: 5,
            ..Default::default()
        };
        let t0 = Instant::now();
        let reqs = shared_prefix_requests(&w, 9, 1, t0, Duration::from_millis(2));
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.arrived, t0 + Duration::from_millis(2 * i as u64));
            assert!(r.max_new_tokens >= 3 && r.max_new_tokens <= 5);
            assert_eq!(r.prompt.len(), w.system_tokens + w.user_tokens);
        }
    }
}
