//! The ragged per-iteration execution seam.
//!
//! Batch-level serving executes rectangles: `batch × SEQ_LEN` tokens,
//! padded. Iteration-level serving executes *one token per live
//! sequence per iteration*, and the sequences have different lengths —
//! an [`IterationBatch`] is ragged by construction and carries no
//! padding for live work. Static batching's rectangle waste is modelled
//! explicitly as [`IterationBatch::pad_slots`]: dead slots the engine
//! still pays for (finished sequences held until their batch drains).
//!
//! [`IterationEngine`] extends [`BatchEngine`] — every iteration engine
//! can still serve the batch-level coordinators, and the continuous
//! scheduler only needs the one extra entry point.

use super::kv_cache::KvCacheManager;
use crate::coordinator::server::BatchEngine;
use anyhow::{anyhow, Result};
use std::time::Duration;

/// One live sequence's slice of an iteration.
#[derive(Debug)]
pub struct SeqSlot<'a> {
    pub seq: u64,
    /// full visible history (prompt + generated), newest last; the
    /// iteration computes the *next* token's logits
    pub tokens: &'a [i32],
    /// KV positions already written for this sequence (== tokens.len()
    /// once the prompt is prefilled)
    pub pos: usize,
    /// positions whose KV this sequence *computed* since it was last
    /// scored (prefill suffix at the admission iteration, 1 in steady
    /// state). Prefix-cache hits enter at their matched offset, so
    /// linked positions never count — this is what engines charge
    /// prefill compute for, and what makes skipped prefill a measurable
    /// TTFT win rather than bookkeeping.
    pub new_tokens: usize,
}

/// A ragged iteration: per-sequence lengths, no padding for live work.
#[derive(Debug, Default)]
pub struct IterationBatch<'a> {
    pub slots: Vec<SeqSlot<'a>>,
    /// dead rectangle slots the executor still pays for (static
    /// batching's padding waste; always 0 under continuous scheduling)
    pub pad_slots: usize,
}

impl IterationBatch<'_> {
    /// Slots the engine pays for (live + dead).
    pub fn width(&self) -> usize {
        self.slots.len() + self.pad_slots
    }
}

/// An engine that can run ragged per-iteration batches on top of its
/// batch-level interface. Returns `slots.len() × vocab` logits — one
/// next-token row per live slot, in slot order.
pub trait IterationEngine: BatchEngine {
    /// KV bytes one token of context costs this engine's model (drives
    /// the [`KvCacheManager`] pool arithmetic).
    fn kv_bytes_per_token(&self) -> usize;

    /// Execute one iteration. `kv` is the paged cache — engines that
    /// model attention state read it (the synthetic engine folds the
    /// stored bytes into its logits, so a corrupted evict/restore
    /// changes tokens); the KV for the tokens generated from these
    /// logits is written back by the scheduler, not the engine.
    fn step(&mut self, batch: &IterationBatch<'_>, kv: &KvCacheManager) -> Result<Vec<f32>>;
}

/// Deterministic iteration engine for artifact-less tests and benches.
///
/// Logits are a pure function of `(seq, stored KV bytes)` — and the KV
/// bytes are themselves a pure function of `(seq, positions, tokens)` —
/// so generated tokens depend only on the request, never on scheduling:
/// continuous and static runs must produce identical responses, and any
/// evict/restore corruption diverges them. Cost model: one iteration
/// sleeps `fixed_cost + per_slot_cost × width` (width counts dead pad
/// slots — the rectangle waste continuous scheduling eliminates).
pub struct SyntheticIterationEngine {
    inner: crate::coordinator::pipeline::SyntheticEngine,
    pub fixed_cost: Duration,
    pub per_slot_cost: Duration,
    /// cost per *prefill* position processed this iteration (each
    /// slot's `new_tokens` beyond the decode token). Zero by default —
    /// the identity/invariant tests don't pay it — but the prefix
    /// bench turns it on so skipped prefill shows up as real TTFT.
    pub prefill_cost: Duration,
    /// iterations executed (scheduling observability for tests)
    pub steps: u64,
    /// live slots summed over iterations
    pub slot_tokens: u64,
    /// prefill positions charged across iterations (Σ new_tokens − 1)
    pub prefill_tokens: u64,
}

impl SyntheticIterationEngine {
    /// Zero-cost engine (pure logits function).
    pub fn instant(vocab: usize) -> Self {
        Self::with_costs(vocab, Duration::ZERO, Duration::ZERO)
    }

    pub fn with_costs(vocab: usize, fixed_cost: Duration, per_slot_cost: Duration) -> Self {
        Self {
            inner: crate::coordinator::pipeline::SyntheticEngine::instant(vocab),
            fixed_cost,
            per_slot_cost,
            prefill_cost: Duration::ZERO,
            steps: 0,
            slot_tokens: 0,
            prefill_tokens: 0,
        }
    }

    /// Charge `cost` per prefill position (builder-style).
    pub fn with_prefill_cost(mut self, cost: Duration) -> Self {
        self.prefill_cost = cost;
        self
    }
}

impl BatchEngine for SyntheticIterationEngine {
    fn vocab(&self) -> usize {
        self.inner.vocab
    }

    fn run_batch(&mut self, tokens: &[i32], batch: usize) -> Result<Vec<f32>> {
        self.inner.run_batch(tokens, batch)
    }
}

impl IterationEngine for SyntheticIterationEngine {
    fn kv_bytes_per_token(&self) -> usize {
        32
    }

    fn step(&mut self, batch: &IterationBatch<'_>, kv: &KvCacheManager) -> Result<Vec<f32>> {
        self.steps += 1;
        self.slot_tokens += batch.slots.len() as u64;
        // the decode token itself is covered by per_slot_cost; every
        // additional unscored position is prefill compute
        let prefill: u64 = batch
            .slots
            .iter()
            .map(|s| s.new_tokens.saturating_sub(1) as u64)
            .sum();
        self.prefill_tokens += prefill;
        let cost = self.fixed_cost
            + self.per_slot_cost * batch.width() as u32
            + self.prefill_cost * prefill as u32;
        if !cost.is_zero() {
            std::thread::sleep(cost);
        }
        let vocab = self.vocab();
        let mut out = Vec::with_capacity(batch.slots.len() * vocab);
        for slot in &batch.slots {
            debug_assert_eq!(slot.pos, slot.tokens.len(), "prefilled history");
            // read the stored KV — the whole point: logits must flow
            // through the paged cache so restores are load-bearing
            let h = kv
                .fold_kv(slot.seq, slot.pos)
                .map_err(|e| anyhow!("synthetic engine KV read: {e}"))?
                ^ slot.seq.wrapping_mul(0x9E3779B97F4A7C15);
            for v in 0..vocab {
                let mut x = h ^ (v as u64).wrapping_mul(0x9E3779B97F4A7C15);
                x ^= x >> 30;
                x = x.wrapping_mul(0xBF58476D1CE4E5B9);
                x ^= x >> 27;
                out.push((x >> 40) as f32 / (1u64 << 24) as f32 - 0.5);
            }
        }
        Ok(out)
    }
}

/// Deterministic argmax (first strict maximum) — the scheduler's greedy
/// token pick. One definition so continuous and static decoding cannot
/// tie-break differently.
pub fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Fp8Format;
    use crate::scheduler::kv_cache::KvCacheConfig;

    fn kv_with(seq: u64, tokens: &[i32]) -> KvCacheManager {
        let mut kv = KvCacheManager::new(KvCacheConfig {
            block_tokens: 4,
            bytes_per_token: 32,
            n_blocks: 16,
            format: Fp8Format::E4M3,
            prefix: None,
        });
        kv.register(seq).unwrap();
        kv.ensure_capacity(seq, tokens.len() + 1).unwrap();
        for &t in tokens {
            kv.write_token(seq, t).unwrap();
        }
        kv
    }

    #[test]
    fn step_is_deterministic_and_kv_dependent() {
        let toks = [3i32, 1, 4, 1, 5];
        let kv = kv_with(9, &toks);
        let mut eng = SyntheticIterationEngine::instant(64);
        let batch = IterationBatch {
            slots: vec![SeqSlot {
                seq: 9,
                tokens: &toks,
                pos: toks.len(),
                new_tokens: toks.len(),
            }],
            pad_slots: 0,
        };
        let a = eng.step(&batch, &kv).unwrap();
        let b = eng.step(&batch, &kv).unwrap();
        assert_eq!(a.len(), 64);
        assert_eq!(a, b, "deterministic");
        // different history → different logits (via the KV bytes)
        let toks2 = [3i32, 1, 4, 1, 6];
        let kv2 = kv_with(9, &toks2);
        let batch2 = IterationBatch {
            slots: vec![SeqSlot {
                seq: 9,
                tokens: &toks2,
                pos: toks2.len(),
                new_tokens: toks2.len(),
            }],
            pad_slots: 0,
        };
        let c = eng.step(&batch2, &kv2).unwrap();
        assert_ne!(a, c);
        assert_eq!(eng.steps, 3);
        assert_eq!(eng.slot_tokens, 3);
    }

    #[test]
    fn ragged_batch_rows_match_solo_rows() {
        // a sequence's logits must not depend on who else is in the
        // iteration — the property that makes continuous == static
        let t1 = [5i32, 6, 7];
        let t2 = [8i32, 9];
        let mut kv = kv_with(1, &t1);
        kv.register(2).unwrap();
        kv.ensure_capacity(2, t2.len() + 1).unwrap();
        for &t in &t2 {
            kv.write_token(2, t).unwrap();
        }
        let mut eng = SyntheticIterationEngine::instant(32);
        let together = eng
            .step(
                &IterationBatch {
                    slots: vec![
                        SeqSlot { seq: 1, tokens: &t1, pos: 3, new_tokens: 1 },
                        SeqSlot { seq: 2, tokens: &t2, pos: 2, new_tokens: 1 },
                    ],
                    pad_slots: 2,
                },
                &kv,
            )
            .unwrap();
        let solo1 = eng
            .step(
                &IterationBatch {
                    slots: vec![SeqSlot { seq: 1, tokens: &t1, pos: 3, new_tokens: 1 }],
                    pad_slots: 0,
                },
                &kv,
            )
            .unwrap();
        let solo2 = eng
            .step(
                &IterationBatch {
                    slots: vec![SeqSlot { seq: 2, tokens: &t2, pos: 2, new_tokens: 1 }],
                    pad_slots: 0,
                },
                &kv,
            )
            .unwrap();
        assert_eq!(&together[..32], &solo1[..]);
        assert_eq!(&together[32..], &solo2[..]);
    }

    #[test]
    fn argmax_is_first_strict_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9, 0.2]), 1);
        assert_eq!(argmax(&[1.0]), 0);
        assert_eq!(argmax(&[-3.0, -1.0, -2.0]), 1);
    }

    #[test]
    fn batch_engine_supertrait_still_serves_rectangles() {
        use crate::runtime::executor::SEQ_LEN;
        let mut eng = SyntheticIterationEngine::instant(16);
        let tokens = vec![1i32; 2 * SEQ_LEN];
        let logits = eng.run_batch(&tokens, 2).unwrap();
        assert_eq!(logits.len(), 2 * 16);
    }
}
