//! Low-precision floating-point formats at the bit level (§2 preliminary).
//!
//! ECF8 compresses the *fields* of FP8 numbers: the 4-bit exponent field is
//! entropy-coded, the sign+mantissa bits are packed raw. This module
//! provides the two standard FP8 formats (E4M3 per Micikevicius et al.,
//! E5M2 = "half of a half") and BF16 (for the DFloat11 baseline), each with
//! exact f32 conversion and field accessors.
//!
//! E4M3 layout: `s eeee mmm`, bias 7. Specials follow the OCP/NVIDIA
//! variant: exponent field 15 with mantissa 111 is NaN; there is **no**
//! infinity — |max| = S.1111.110 = 448. Subnormals: exponent field 0,
//! value = ±m/8 · 2^-6.

/// An FP8 E4M3 value, stored as its raw byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
#[repr(transparent)]
pub struct F8E4M3(pub u8);

/// An FP8 E5M2 value, stored as its raw byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
#[repr(transparent)]
pub struct F8E5M2(pub u8);

/// A BF16 value, stored as its raw u16 (upper half of an f32).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
#[repr(transparent)]
pub struct BF16(pub u16);

impl F8E4M3 {
    pub const EXP_BITS: u32 = 4;
    pub const MAN_BITS: u32 = 3;
    pub const BIAS: i32 = 7;
    /// Largest finite magnitude (0x7E = 0.1111.110).
    pub const MAX: f32 = 448.0;
    pub const NAN: F8E4M3 = F8E4M3(0x7F);

    #[inline]
    pub fn from_bits(b: u8) -> Self {
        F8E4M3(b)
    }

    #[inline]
    pub fn to_bits(self) -> u8 {
        self.0
    }

    /// Sign bit (0 or 1).
    #[inline]
    pub fn sign(self) -> u8 {
        self.0 >> 7
    }

    /// Raw 4-bit exponent field (0..=15). This is the symbol ECF8
    /// entropy-codes.
    #[inline]
    pub fn exponent_field(self) -> u8 {
        (self.0 >> 3) & 0x0F
    }

    /// Raw 3-bit mantissa field.
    #[inline]
    pub fn mantissa_field(self) -> u8 {
        self.0 & 0x07
    }

    /// The packed sign/mantissa nibble `s mmm` the ECF8 container stores
    /// verbatim (Algorithm 1's `packed` stream).
    #[inline]
    pub fn sign_mantissa_nibble(self) -> u8 {
        ((self.0 >> 4) & 0x08) | (self.0 & 0x07)
    }

    /// Reassemble from an exponent field and a sign/mantissa nibble.
    #[inline]
    pub fn from_fields(exp_field: u8, sign_man_nibble: u8) -> Self {
        debug_assert!(exp_field < 16 && sign_man_nibble < 16);
        F8E4M3(((sign_man_nibble & 0x08) << 4) | (exp_field << 3) | (sign_man_nibble & 0x07))
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7F) == 0x7F
    }

    /// Exact conversion to f32 (every E4M3 value is representable).
    pub fn to_f32(self) -> f32 {
        let s = if self.sign() == 1 { -1.0f32 } else { 1.0 };
        let e = self.exponent_field() as i32;
        let m = self.mantissa_field() as f32;
        if self.is_nan() {
            return f32::NAN;
        }
        if e == 0 {
            // subnormal: ±(m/8) · 2^{1-bias}
            s * (m / 8.0) * (2.0f32).powi(1 - Self::BIAS)
        } else {
            s * (1.0 + m / 8.0) * (2.0f32).powi(e - Self::BIAS)
        }
    }

    /// Round-to-nearest-even conversion from f32, saturating to ±MAX
    /// (matches PyTorch's `to(torch.float8_e4m3fn)` semantics).
    pub fn from_f32(x: f32) -> Self {
        if x.is_nan() {
            return Self::NAN;
        }
        let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
        let a = x.abs();
        if a == 0.0 {
            return F8E4M3(sign);
        }
        if a >= 464.0 {
            // midpoint between 448 (max) and the would-be 480: values
            // >= 464 would round up past MAX; saturate.
            return F8E4M3(sign | 0x7E);
        }
        // scale into E4M3's grid: find e such that a = (1+f) 2^(e-7)
        let bits = a.to_bits();
        let exp32 = ((bits >> 23) & 0xFF) as i32 - 127;
        let e = exp32 + Self::BIAS; // target biased exponent
        if e >= 16 {
            return F8E4M3(sign | 0x7E); // saturate (covers a < 464, e.g. 460 -> 448)
        }
        if e <= 0 {
            // subnormal target: quantise a / 2^{1-bias} * 8 = a * 2^{bias-1} * 8
            let q = a * (2.0f32).powi(Self::BIAS - 1) * 8.0;
            let r = round_nearest_even(q);
            if r >= 8.0 {
                return F8E4M3(sign | (1 << 3)); // rounds up into normal range
            }
            if r <= 0.0 {
                return F8E4M3(sign);
            }
            return F8E4M3(sign | (r as u8));
        }
        // normal target: mantissa fraction in [0,1) scaled by 8
        let frac = f32::from_bits((bits & 0x007F_FFFF) | 0x3F80_0000) - 1.0; // [0,1)
        let q = frac * 8.0;
        let mut m = round_nearest_even(q) as u32;
        let mut e = e as u32;
        if m >= 8 {
            m = 0;
            e += 1;
            if e >= 16 || (e == 15 && m == 7) {
                return F8E4M3(sign | 0x7E);
            }
        }
        if e == 15 && m == 7 {
            // would collide with NaN encoding; round down to max finite
            return F8E4M3(sign | 0x7E);
        }
        F8E4M3(sign | ((e as u8) << 3) | (m as u8))
    }
}

#[inline]
fn round_nearest_even(x: f32) -> f32 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 {
        // halfway: round to even
        let f = x.floor();
        if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    } else {
        r
    }
}

impl F8E5M2 {
    pub const EXP_BITS: u32 = 5;
    pub const MAN_BITS: u32 = 2;
    pub const BIAS: i32 = 15;
    pub const MAX: f32 = 57344.0;

    #[inline]
    pub fn from_bits(b: u8) -> Self {
        F8E5M2(b)
    }

    #[inline]
    pub fn to_bits(self) -> u8 {
        self.0
    }

    #[inline]
    pub fn sign(self) -> u8 {
        self.0 >> 7
    }

    /// Raw 5-bit exponent field (0..=31).
    #[inline]
    pub fn exponent_field(self) -> u8 {
        (self.0 >> 2) & 0x1F
    }

    #[inline]
    pub fn mantissa_field(self) -> u8 {
        self.0 & 0x03
    }

    pub fn is_nan(self) -> bool {
        self.exponent_field() == 31 && self.mantissa_field() != 0
    }

    pub fn is_infinite(self) -> bool {
        self.exponent_field() == 31 && self.mantissa_field() == 0
    }

    /// Exact conversion to f32. E5M2 is a true IEEE mini-float (with Inf).
    pub fn to_f32(self) -> f32 {
        let s = if self.sign() == 1 { -1.0f32 } else { 1.0 };
        let e = self.exponent_field() as i32;
        let m = self.mantissa_field() as f32;
        if e == 31 {
            return if m == 0.0 { s * f32::INFINITY } else { f32::NAN };
        }
        if e == 0 {
            s * (m / 4.0) * (2.0f32).powi(1 - Self::BIAS)
        } else {
            s * (1.0 + m / 4.0) * (2.0f32).powi(e - Self::BIAS)
        }
    }

    /// E5M2 from f32 — exact truncation path via f16-style rounding:
    /// round-to-nearest-even in the 2-bit mantissa, overflow to Inf.
    pub fn from_f32(x: f32) -> Self {
        if x.is_nan() {
            return F8E5M2(0x7F);
        }
        let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
        let a = x.abs();
        if a == 0.0 {
            return F8E5M2(sign);
        }
        if a.is_infinite() || a >= 61440.0 {
            return F8E5M2(sign | 0x7C); // Inf
        }
        let bits = a.to_bits();
        let exp32 = ((bits >> 23) & 0xFF) as i32 - 127;
        let e = exp32 + Self::BIAS;
        if e <= 0 {
            let q = a * (2.0f32).powi(Self::BIAS - 1) * 4.0;
            let r = round_nearest_even(q);
            if r >= 4.0 {
                return F8E5M2(sign | (1 << 2));
            }
            if r <= 0.0 {
                return F8E5M2(sign);
            }
            return F8E5M2(sign | (r as u8));
        }
        let frac = f32::from_bits((bits & 0x007F_FFFF) | 0x3F80_0000) - 1.0;
        let mut m = round_nearest_even(frac * 4.0) as u32;
        let mut e = e as u32;
        if m >= 4 {
            m = 0;
            e += 1;
        }
        if e >= 31 {
            return F8E5M2(sign | 0x7C);
        }
        F8E5M2(sign | ((e as u8) << 2) | (m as u8))
    }
}

impl BF16 {
    pub const EXP_BITS: u32 = 8;
    pub const MAN_BITS: u32 = 7;

    #[inline]
    pub fn from_bits(b: u16) -> Self {
        BF16(b)
    }

    #[inline]
    pub fn to_bits(self) -> u16 {
        self.0
    }

    /// Raw 8-bit exponent field — the symbol DFloat11 entropy-codes.
    #[inline]
    pub fn exponent_field(self) -> u8 {
        ((self.0 >> 7) & 0xFF) as u8
    }

    #[inline]
    pub fn sign(self) -> u8 {
        (self.0 >> 15) as u8
    }

    #[inline]
    pub fn mantissa_field(self) -> u8 {
        (self.0 & 0x7F) as u8
    }

    /// Truncating conversion (the standard BF16 cast used in training).
    pub fn from_f32_truncate(x: f32) -> Self {
        BF16((x.to_bits() >> 16) as u16)
    }

    /// Round-to-nearest-even conversion.
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        if x.is_nan() {
            return BF16(((bits >> 16) as u16) | 0x0040); // quiet
        }
        let round_bit = (bits >> 15) & 1;
        let sticky = bits & 0x7FFF;
        let mut hi = (bits >> 16) as u16;
        if round_bit == 1 && (sticky != 0 || (hi & 1) == 1) {
            hi = hi.wrapping_add(1);
        }
        BF16(hi)
    }

    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

/// Bulk conversions over raw byte tensors (used by weight generation and
/// the runtime's decode-to-f32 path).
pub fn e4m3_bytes_to_f32(src: &[u8], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    // table-driven: one 256-entry LUT beats per-element branching
    let lut = e4m3_f32_table();
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d = lut[s as usize];
    }
}

/// All 256 E4M3 values as f32 (NaNs included).
pub fn e4m3_f32_table() -> &'static [f32; 256] {
    use once_cell::sync::Lazy;
    static TABLE: Lazy<[f32; 256]> = Lazy::new(|| {
        let mut t = [0.0f32; 256];
        for b in 0..=255u8 {
            t[b as usize] = F8E4M3(b).to_f32();
        }
        t
    });
    &TABLE
}

/// Cast an f32 slice to E4M3 bytes (round-nearest-even, saturating).
pub fn f32_to_e4m3_bytes(src: &[f32], dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d = F8E4M3::from_f32(s).to_bits();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_known_values() {
        assert_eq!(F8E4M3::from_f32(1.0).to_bits(), 0x38); // e=7,m=0
        assert_eq!(F8E4M3::from_f32(-1.0).to_bits(), 0xB8);
        assert_eq!(F8E4M3::from_f32(448.0).to_bits(), 0x7E);
        assert_eq!(F8E4M3::from_f32(0.0).to_bits(), 0x00);
        assert_eq!(F8E4M3::from_f32(-0.0).to_bits(), 0x80);
        assert_eq!(F8E4M3(0x38).to_f32(), 1.0);
        assert_eq!(F8E4M3(0x7E).to_f32(), 448.0);
        // smallest subnormal = 2^-9
        assert_eq!(F8E4M3(0x01).to_f32(), 2.0f32.powi(-9));
    }

    #[test]
    fn e4m3_nan_and_saturation() {
        assert!(F8E4M3::from_f32(f32::NAN).is_nan());
        assert!(F8E4M3::from_f32(f32::NAN).to_f32().is_nan());
        assert_eq!(F8E4M3::from_f32(1e9).to_f32(), 448.0);
        assert_eq!(F8E4M3::from_f32(f32::INFINITY).to_f32(), 448.0);
        assert_eq!(F8E4M3::from_f32(-1e9).to_f32(), -448.0);
    }

    #[test]
    fn e4m3_roundtrip_all_256() {
        // Every E4M3 bit pattern must round-trip exactly through f32.
        for b in 0..=255u8 {
            let v = F8E4M3(b);
            if v.is_nan() {
                assert!(F8E4M3::from_f32(v.to_f32()).is_nan());
                continue;
            }
            let back = F8E4M3::from_f32(v.to_f32());
            // -0.0/+0.0 keep their sign bit
            assert_eq!(back.to_bits(), b, "bits {b:#04x} -> {} -> {:#04x}", v.to_f32(), back.to_bits());
        }
    }

    #[test]
    fn e4m3_field_extraction_and_reassembly() {
        for b in 0..=255u8 {
            let v = F8E4M3(b);
            let re = F8E4M3::from_fields(v.exponent_field(), v.sign_mantissa_nibble());
            assert_eq!(re.to_bits(), b);
        }
    }

    #[test]
    fn e4m3_rounding_nearest_even() {
        // halfway between 1.0 (m=0) and 1.125 (m=1) is 1.0625 -> even (m=0)
        assert_eq!(F8E4M3::from_f32(1.0625).to_bits(), 0x38);
        // halfway between 1.125 and 1.25 -> even (m=2)
        assert_eq!(F8E4M3::from_f32(1.1875).to_bits(), 0x3A);
    }

    #[test]
    fn e4m3_subnormals() {
        let tiny = 2.0f32.powi(-9); // smallest subnormal
        assert_eq!(F8E4M3::from_f32(tiny).to_bits(), 0x01);
        assert_eq!(F8E4M3::from_f32(tiny * 7.0).to_bits(), 0x07);
        // just below half the smallest subnormal flushes to zero
        assert_eq!(F8E4M3::from_f32(tiny * 0.49).to_bits(), 0x00);
        // largest subnormal + half step rounds into normals
        let x = 2.0f32.powi(-6) * (7.5 / 8.0);
        assert_eq!(F8E4M3::from_f32(x).to_bits(), 0x08);
    }

    #[test]
    fn e5m2_known_values() {
        assert_eq!(F8E5M2::from_f32(1.0).to_bits(), 0x3C); // e=15,m=0
        assert_eq!(F8E5M2(0x3C).to_f32(), 1.0);
        assert!(F8E5M2::from_f32(f32::INFINITY).is_infinite());
        assert!(F8E5M2::from_f32(1e9).is_infinite());
        assert!(F8E5M2::from_f32(f32::NAN).is_nan());
        assert_eq!(F8E5M2::from_f32(57344.0).to_f32(), 57344.0);
    }

    #[test]
    fn e5m2_roundtrip_all_finite() {
        for b in 0..=255u8 {
            let v = F8E5M2(b);
            if v.is_nan() {
                continue;
            }
            let back = F8E5M2::from_f32(v.to_f32());
            assert_eq!(back.to_bits(), b, "bits {b:#04x}");
        }
    }

    #[test]
    fn bf16_roundtrip_and_fields() {
        let x = 3.140625f32; // exactly representable in bf16? check round trip stability
        let b = BF16::from_f32(x);
        let x2 = b.to_f32();
        let b2 = BF16::from_f32(x2);
        assert_eq!(b.to_bits(), b2.to_bits());
        assert_eq!(BF16::from_f32(1.0).exponent_field(), 127);
        assert_eq!(BF16::from_f32(-2.0).sign(), 1);
        assert_eq!(BF16::from_f32(2.0).exponent_field(), 128);
    }

    #[test]
    fn bf16_round_nearest_even() {
        // 1 + 2^-8 is exactly halfway between bf16(1.0) and the next value;
        // even mantissa (0) wins.
        let x = 1.0 + 2f32.powi(-8);
        assert_eq!(BF16::from_f32(x).to_bits(), BF16::from_f32(1.0).to_bits());
        // slightly above halfway rounds up
        let y = 1.0 + 2f32.powi(-8) + 2f32.powi(-12);
        assert_eq!(BF16::from_f32(y).to_bits(), BF16::from_f32(1.0).to_bits() + 1);
    }

    #[test]
    fn bulk_conversion_matches_scalar() {
        let bytes: Vec<u8> = (0..=255u8).filter(|b| !F8E4M3(*b).is_nan()).collect();
        let mut out = vec![0f32; bytes.len()];
        e4m3_bytes_to_f32(&bytes, &mut out);
        for (i, &b) in bytes.iter().enumerate() {
            assert_eq!(out[i], F8E4M3(b).to_f32());
        }
        let mut back = vec![0u8; bytes.len()];
        f32_to_e4m3_bytes(&out, &mut back);
        assert_eq!(back, bytes);
    }

    #[test]
    fn exponent_field_is_high_nibble_sans_sign() {
        let v = F8E4M3(0b1_1010_011);
        assert_eq!(v.sign(), 1);
        assert_eq!(v.exponent_field(), 0b1010);
        assert_eq!(v.mantissa_field(), 0b011);
        assert_eq!(v.sign_mantissa_nibble(), 0b1011);
    }
}
