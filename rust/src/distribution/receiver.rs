//! The receiving side: reassemble packets into blocks, FEC-repair
//! missing source symbols, verify every byte, and commit files into a
//! servable model directory under the store's tmp+rename discipline.
//!
//! Trust nothing from the wire. The verification ladder a byte climbs
//! before it becomes servable:
//!
//! 1. **frame CRC** — [`parse_packet`] rejects any flipped or truncated
//!    frame (the fault channel's bit-flips and truncations die here);
//! 2. **geometry consistency** — packets of one block must agree on its
//!    FEC parameters, length, and offset;
//! 3. **record CRC** — a fully reassembled shard is `walk_shard`ed:
//!    every record header re-parsed, every payload CRC re-verified (the
//!    index re-parses under its own trailing CRC);
//! 4. **index cross-check** — once the index is known, each shard's
//!    records are checked against the index's location + CRC entries;
//! 5. **tmp+rename commit** — bytes appear in the output directory
//!    atomically, never half-written.
//!
//! Anything that fails any rung becomes a structured [`DistError`] in
//! the report — never a panic, never a silently corrupt committed file.
//! As streams commit, the receiver publishes executor stages on an
//! [`AvailabilityMap`], which is what makes serve-while-downloading
//! safe: a stage is published only when every shard its tensors live in
//! has fully committed.

use super::availability::AvailabilityMap;
use super::fec::fec_for;
use super::sender::{parse_packet, Manifest, PacketHeader, STREAM_INDEX, STREAM_MANIFEST};
use super::transport::Transport;
use super::DistError;
use crate::codec::container::{
    shard_file_name, walk_shard, RecordHeader, TensorIndex, INDEX_FILE, RECORD_HEADER_BYTES,
};
use crate::model::config::BlockType;
use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Cap on the structured-error samples kept in the report (counters keep
/// counting past it).
const MAX_ERROR_SAMPLES: usize = 16;

#[derive(Debug)]
struct BlockState {
    header: PacketHeader,
    symbols: Vec<Option<Vec<u8>>>,
    have: usize,
    decoded: bool,
}

#[derive(Debug, Default)]
struct StreamBuf {
    buf: Vec<u8>,
    done: HashSet<u32>,
}

/// Tally + structured-error log of one transfer.
#[derive(Debug, Clone, Default)]
pub struct RecvReport {
    /// frames pulled off the transport
    pub packets: u64,
    /// frames rejected at parse (bad magic/version, truncation, CRC)
    pub bad_packets: u64,
    /// valid frames that added nothing (duplicates, symbols of
    /// already-decoded blocks, extra manifest copies)
    pub redundant: u64,
    pub blocks_decoded: u64,
    /// decoded blocks that needed parity (≥ 1 source symbol was lost)
    pub blocks_repaired: u64,
    pub streams_committed: u64,
    pub bytes_committed: u64,
    /// retransmission rounds requested via [`Receiver::missing_blocks`]
    pub retransmit_rounds: u64,
    /// cumulative blocks requested across those rounds
    pub retransmit_blocks: u64,
    /// first [`MAX_ERROR_SAMPLES`] structured errors, rendered
    pub errors: Vec<String>,
}

impl RecvReport {
    fn record(&mut self, e: &DistError) {
        if self.errors.len() < MAX_ERROR_SAMPLES {
            self.errors.push(e.to_string());
        }
    }
}

/// The receiving half of a transfer. Feed it frames with
/// [`ingest`](Self::ingest) (or [`drain`](Self::drain) a transport);
/// files commit into `out_dir` as they complete and verify.
pub struct Receiver {
    out_dir: PathBuf,
    manifest: Option<Manifest>,
    blocks: HashMap<(u16, u32), BlockState>,
    streams: HashMap<u16, StreamBuf>,
    committed: HashSet<u16>,
    index: Option<TensorIndex>,
    availability: Option<Arc<AvailabilityMap>>,
    /// per availability unit: shard streams it still waits on
    unit_pending: Vec<HashSet<u16>>,
    report: RecvReport,
}

impl Receiver {
    pub fn new(out_dir: &Path) -> Self {
        Self {
            out_dir: out_dir.to_path_buf(),
            manifest: None,
            blocks: HashMap::new(),
            streams: HashMap::new(),
            committed: HashSet::new(),
            index: None,
            availability: None,
            unit_pending: Vec::new(),
            report: RecvReport::default(),
        }
    }

    /// Attach the availability map serving blocks on. Units publish as
    /// their shards commit; if the transfer is already past that point
    /// the map catches up immediately.
    pub fn set_availability(&mut self, map: Arc<AvailabilityMap>) {
        self.availability = Some(map);
        if self.index.is_some() {
            self.rebuild_unit_pending();
            self.publish_ready_units();
        }
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    pub fn report(&self) -> &RecvReport {
        &self.report
    }

    /// Ingest one frame. Malformed frames and block-level failures are
    /// counted and sampled into the report *and* returned — the caller
    /// may ignore the error (the fault sweep does) without losing it.
    pub fn ingest(&mut self, frame: &[u8]) -> Result<(), DistError> {
        self.report.packets += 1;
        match self.ingest_inner(frame) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.report.record(&e);
                Err(e)
            }
        }
    }

    fn ingest_inner(&mut self, frame: &[u8]) -> Result<(), DistError> {
        let (h, payload) = match parse_packet(frame) {
            Ok(ok) => ok,
            Err(e) => {
                self.report.bad_packets += 1;
                return Err(e);
            }
        };
        if h.is_control() {
            return self.ingest_manifest(payload);
        }
        self.ingest_symbol(h, payload)
    }

    /// Pull every pending frame off a transport; returns how many.
    pub fn drain(&mut self, t: &mut dyn Transport) -> usize {
        let mut n = 0;
        while let Some(frame) = t.recv() {
            let _ = self.ingest(&frame);
            n += 1;
        }
        n
    }

    fn ingest_manifest(&mut self, payload: &[u8]) -> Result<(), DistError> {
        let m = match Manifest::decode(payload) {
            Ok(m) => m,
            Err(e) => {
                self.report.bad_packets += 1;
                return Err(e);
            }
        };
        if self.manifest.is_some() {
            self.report.redundant += 1;
            return Ok(());
        }
        self.manifest = Some(m);
        // blocks may have fully decoded before the manifest arrived; a
        // failure in one stream must not block committing the others
        let streams: Vec<u16> = self.streams.keys().copied().collect();
        let mut first_err = None;
        for s in streams {
            if let Err(e) = self.try_commit_stream(s) {
                if first_err.is_none() {
                    first_err = Some(e);
                } else {
                    self.report.record(&e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn ingest_symbol(&mut self, h: PacketHeader, payload: &[u8]) -> Result<(), DistError> {
        if self.committed.contains(&h.stream) {
            self.report.redundant += 1;
            return Ok(());
        }
        let key = (h.stream, h.block);
        let params = h.params()?; // validated by parse, cheap re-derive
        let state = self.blocks.entry(key).or_insert_with(|| BlockState {
            header: h,
            symbols: vec![None; params.n()],
            have: 0,
            decoded: false,
        });
        if state.decoded {
            self.report.redundant += 1;
            return Ok(());
        }
        let first = &state.header;
        if (first.fec, first.k, first.parity, first.symbol_bytes, first.block_bytes, first.block_offset)
            != (h.fec, h.k, h.parity, h.symbol_bytes, h.block_bytes, h.block_offset)
        {
            // keep the first-seen geometry; both variants passed their
            // frame CRCs, so this is a sender bug, not line noise
            return Err(DistError::BlockInconsistent {
                stream: h.stream,
                block: h.block,
                what: "packets disagree on block geometry",
            });
        }
        let slot = h.symbol as usize;
        if state.symbols[slot].is_some() {
            self.report.redundant += 1;
            return Ok(());
        }
        state.symbols[slot] = Some(payload.to_vec());
        state.have += 1;
        if state.have < params.k as usize {
            return Ok(());
        }
        // enough symbols — try to decode (NoCode may still refuse if the
        // present set isn't exactly the source symbols)
        let missing_source = state.symbols[..params.k as usize]
            .iter()
            .filter(|s| s.is_none())
            .count();
        let codec = fec_for(params.fec.as_u8()).ok_or(DistError::UnknownFec(params.fec.as_u8()))?;
        match codec.recover(&params, &mut state.symbols) {
            Ok(()) => {}
            Err(DistError::NeedMoreSymbols { .. }) => return Ok(()),
            Err(e) => return Err(e),
        }
        // splice the true-length block into the stream buffer
        let mut block = Vec::with_capacity(params.n() * params.symbol_bytes as usize);
        for s in state.symbols[..params.k as usize].iter() {
            block.extend_from_slice(s.as_ref().expect("recovered source symbol"));
        }
        block.truncate(h.block_bytes as usize);
        if block.len() != h.block_bytes as usize {
            return Err(DistError::BlockInconsistent {
                stream: h.stream,
                block: h.block,
                what: "block_bytes exceeds k * symbol_bytes",
            });
        }
        state.decoded = true;
        state.symbols = Vec::new(); // free the receive window
        self.report.blocks_decoded += 1;
        if missing_source > 0 {
            self.report.blocks_repaired += 1;
        }
        let sb = self.streams.entry(h.stream).or_default();
        let start = h.block_offset as usize;
        let end = start + block.len();
        if sb.buf.len() < end {
            sb.buf.resize(end, 0);
        }
        sb.buf[start..end].copy_from_slice(&block);
        sb.done.insert(h.block);
        self.try_commit_stream(h.stream)
    }

    /// Commit `stream` if the manifest says it is complete, running the
    /// record-level verification ladder first.
    fn try_commit_stream(&mut self, stream: u16) -> Result<(), DistError> {
        let Some(manifest) = &self.manifest else {
            return Ok(());
        };
        if self.committed.contains(&stream) {
            return Ok(());
        }
        let Some(entry) = manifest.streams.iter().find(|s| s.stream == stream) else {
            return Err(DistError::BlockInconsistent {
                stream,
                block: 0,
                what: "stream not in manifest",
            });
        };
        let Some(sb) = self.streams.get(&stream) else {
            return Ok(());
        };
        if (sb.done.len() as u32) < entry.n_blocks {
            return Ok(());
        }
        if sb.buf.len() as u64 != entry.file_len {
            return Err(DistError::BlockInconsistent {
                stream,
                block: 0,
                what: "reassembled length disagrees with manifest",
            });
        }
        // rung 3: full record-level verification
        if stream == STREAM_INDEX {
            let index = TensorIndex::deserialize(&sb.buf).map_err(|e| DistError::RecordCorrupt {
                stream,
                what: e.to_string(),
            })?;
            self.commit_file(INDEX_FILE, stream)?;
            self.index = Some(index);
            self.rebuild_unit_pending();
            // rung 4 for shards that committed before the index arrived
            let already: Vec<u16> = self.committed.iter().copied().filter(|&s| s != STREAM_INDEX).collect();
            for s in already {
                self.cross_check_shard(s)?;
            }
            self.publish_ready_units();
            return Ok(());
        }
        walk_shard(&sb.buf).map_err(|e| DistError::RecordCorrupt {
            stream,
            what: e.to_string(),
        })?;
        self.commit_file(&shard_file_name(stream as u32), stream)?;
        if self.index.is_some() {
            self.cross_check_shard(stream)?;
        }
        self.publish_ready_units();
        Ok(())
    }

    /// Rung 5: write the reassembled stream to a tmp file and rename it
    /// into place — the same commit discipline the store writer uses, so
    /// a crashed transfer never leaves a half-written servable file.
    fn commit_file(&mut self, name: &str, stream: u16) -> Result<(), DistError> {
        let sb = self.streams.get(&stream).expect("stream buffer present");
        std::fs::create_dir_all(&self.out_dir)?;
        let tmp = self.out_dir.join(format!("{name}.tmp"));
        std::fs::write(&tmp, &sb.buf)?;
        let fin = self.out_dir.join(name);
        let _ = std::fs::remove_file(&fin);
        std::fs::rename(&tmp, &fin)?;
        self.report.streams_committed += 1;
        self.report.bytes_committed += sb.buf.len() as u64;
        self.committed.insert(stream);
        Ok(())
    }

    /// Rung 4: every index entry living in `stream` must match the
    /// committed bytes — right header at the right offset, matching
    /// payload CRC and length. Catches a self-consistent-but-wrong
    /// record that record-level CRCs alone cannot.
    fn cross_check_shard(&mut self, stream: u16) -> Result<(), DistError> {
        let index = self.index.as_ref().expect("index present");
        let data = std::fs::read(self.out_dir.join(shard_file_name(stream as u32)))?;
        for e in index.entries.iter().filter(|e| e.shard == stream as u32) {
            let off = e.offset as usize;
            let len = e.len as usize;
            let fail = |what: String| DistError::RecordCorrupt { stream, what };
            if off + len > data.len() || len < RECORD_HEADER_BYTES {
                return Err(fail(format!("entry '{}' range outside shard", e.name)));
            }
            let h = RecordHeader::parse(&data[off..]).map_err(|er| {
                fail(format!("entry '{}': {er}", e.name))
            })?;
            if h.record_len() != e.len || h.payload_crc != e.payload_crc {
                return Err(fail(format!("entry '{}' disagrees with index", e.name)));
            }
        }
        Ok(())
    }

    /// Map index entries onto availability units (executor stages):
    /// unit 0 = embedding, 1..=L = layers, L+1 = head and everything
    /// else. Each unit waits on the set of shards its tensors live in.
    fn rebuild_unit_pending(&mut self) {
        let Some(index) = &self.index else { return };
        let n_layers = index
            .entries
            .iter()
            .filter(|e| BlockType::code_is_layer_weight(e.block_type))
            .map(|e| e.layer as usize + 1)
            .max()
            .unwrap_or(0);
        let n_units = n_layers + 2;
        let mut pending: Vec<HashSet<u16>> = vec![HashSet::new(); n_units];
        for e in &index.entries {
            let unit = if BlockType::code_is_layer_weight(e.block_type) {
                e.layer as usize + 1
            } else if BlockType::from_code(e.block_type) == Some(BlockType::Embedding) {
                0
            } else {
                n_units - 1
            };
            pending[unit].insert(e.shard as u16);
        }
        self.unit_pending = pending;
    }

    fn publish_ready_units(&mut self) {
        let Some(map) = &self.availability else { return };
        if self.index.is_none() {
            return;
        }
        for (unit, shards) in self.unit_pending.iter().enumerate() {
            if shards.iter().all(|s| self.committed.contains(s)) {
                map.publish(unit);
            }
        }
    }

    /// Is every manifest stream committed?
    pub fn is_complete(&self) -> bool {
        match &self.manifest {
            Some(m) => m.streams.iter().all(|s| self.committed.contains(&s.stream)),
            None => false,
        }
    }

    /// What a retransmission round should carry: every undecoded block
    /// of every known stream (the manifest itself when it never
    /// arrived). Empty means the transfer is complete. Each non-empty
    /// call is tallied as one re-request round.
    pub fn missing_blocks(&mut self) -> Vec<(u16, u32)> {
        let mut missing = Vec::new();
        match &self.manifest {
            None => missing.push((STREAM_MANIFEST, 0)),
            Some(m) => {
                for s in &m.streams {
                    if self.committed.contains(&s.stream) {
                        continue;
                    }
                    let done = self.streams.get(&s.stream);
                    for block in 0..s.n_blocks {
                        if !done.is_some_and(|b| b.done.contains(&block)) {
                            missing.push((s.stream, block));
                        }
                    }
                }
            }
        }
        if !missing.is_empty() {
            self.report.retransmit_rounds += 1;
            self.report.retransmit_blocks += missing.len() as u64;
        }
        missing
    }

    /// Final verdict: `Ok(report)` when every stream committed, a
    /// structured [`DistError::Incomplete`] (report still retrievable
    /// via [`report`](Self::report)) otherwise.
    pub fn finish(&mut self) -> Result<RecvReport, DistError> {
        if self.is_complete() {
            return Ok(self.report.clone());
        }
        let missing = self.missing_blocks();
        // finish() is a verdict, not a re-request — undo the tally
        if !missing.is_empty() {
            self.report.retransmit_rounds -= 1;
            self.report.retransmit_blocks -= missing.len() as u64;
        }
        let e = DistError::Incomplete {
            missing: missing.len().max(1),
        };
        self.report.record(&e);
        Err(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distribution::sender::{
        tests::synth_shard, Sender, SenderConfig, STREAM_INDEX,
    };
    use crate::distribution::transport::{FaultPlan, FaultyChannel, LosslessChannel};
    use crate::distribution::FecId;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ecf8-recv-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// An index whose entries exactly describe `shard` (stream 0).
    fn index_for_shard(shard: &[u8]) -> TensorIndex {
        let records = walk_shard(shard).unwrap();
        let entries = records
            .iter()
            .enumerate()
            .map(|(i, (h, range))| crate::codec::container::IndexEntry {
                name: format!("t{i}"),
                rows: 1,
                cols: h.n_elem,
                layer: i as u32,
                block_type: 1, // a layer weight
                codec: h.codec,
                format: h.format,
                shard: 0,
                offset: (range.start - RECORD_HEADER_BYTES) as u64,
                len: RECORD_HEADER_BYTES as u64 + h.payload_len,
                payload_crc: h.payload_crc,
            })
            .collect();
        TensorIndex {
            model: "synth".into(),
            n_shards: 1,
            entries,
            layer_extents: Vec::new(),
        }
    }

    fn sender_for(shard: Vec<u8>, index_bytes: Vec<u8>, cfg: &SenderConfig) -> Sender {
        Sender::from_parts("synth", vec![(0u16, shard), (STREAM_INDEX, index_bytes)], cfg).unwrap()
    }

    #[test]
    fn lossless_transfer_is_byte_identical() {
        let shard = synth_shard(0, 6, 2000, 11);
        let index = index_for_shard(&shard);
        let cfg = SenderConfig {
            block_bytes: 4096,
            symbol_bytes: 256,
            ..SenderConfig::default()
        };
        let sender = sender_for(shard.clone(), index.serialize(), &cfg);
        let out = tmp_dir("lossless");
        let mut rx = Receiver::new(&out);
        let mut ch = LosslessChannel::default();
        sender.send_all(&mut ch).unwrap();
        rx.drain(&mut ch);
        let report = rx.finish().unwrap();
        assert_eq!(report.bad_packets, 0);
        assert_eq!(report.streams_committed, 2);
        assert_eq!(std::fs::read(out.join(shard_file_name(0))).unwrap(), shard);
        assert_eq!(
            std::fs::read(out.join(INDEX_FILE)).unwrap(),
            index.serialize()
        );
        let _ = std::fs::remove_dir_all(&out);
    }

    #[test]
    fn loss_within_parity_budget_repairs_exactly() {
        let shard = synth_shard(0, 8, 3000, 23);
        let index = index_for_shard(&shard);
        let cfg = SenderConfig {
            block_bytes: 4096,
            symbol_bytes: 256,
            parity_ratio: 0.5,
            ..SenderConfig::default()
        };
        let sender = sender_for(shard.clone(), index.serialize(), &cfg);
        let out = tmp_dir("lossy");
        let mut rx = Receiver::new(&out);
        let mut ch = FaultyChannel::new(FaultPlan::loss(3, 0.15));
        sender.send_all(&mut ch).unwrap();
        rx.drain(&mut ch);
        // single-digit retransmission rounds finish the tail
        for _ in 0..8 {
            if rx.is_complete() {
                break;
            }
            let missing = rx.missing_blocks();
            sender.send_blocks(&mut ch, &missing).unwrap();
            rx.drain(&mut ch);
        }
        let report = rx.finish().unwrap();
        assert!(report.blocks_repaired > 0, "loss plan produced no repairs");
        assert_eq!(std::fs::read(out.join(shard_file_name(0))).unwrap(), shard);
        let _ = std::fs::remove_dir_all(&out);
    }

    #[test]
    fn gauntlet_corruption_never_commits_bad_bytes() {
        let shard = synth_shard(0, 8, 2500, 31);
        let index = index_for_shard(&shard);
        let cfg = SenderConfig {
            block_bytes: 4096,
            symbol_bytes: 256,
            ..SenderConfig::default()
        };
        let sender = sender_for(shard.clone(), index.serialize(), &cfg);
        let out = tmp_dir("gauntlet");
        let mut rx = Receiver::new(&out);
        let mut ch = FaultyChannel::new(FaultPlan::gauntlet(5, 0.2));
        sender.send_all(&mut ch).unwrap();
        rx.drain(&mut ch);
        for _ in 0..12 {
            if rx.is_complete() {
                break;
            }
            let missing = rx.missing_blocks();
            sender.send_blocks(&mut ch, &missing).unwrap();
            rx.drain(&mut ch);
        }
        let report = rx.finish().unwrap();
        assert!(report.bad_packets > 0, "gauntlet produced no bad frames");
        assert_eq!(std::fs::read(out.join(shard_file_name(0))).unwrap(), shard);
        let _ = std::fs::remove_dir_all(&out);
    }

    #[test]
    fn loss_beyond_budget_reports_structured_incomplete() {
        let shard = synth_shard(0, 8, 3000, 47);
        let index = index_for_shard(&shard);
        let cfg = SenderConfig {
            block_bytes: 4096,
            symbol_bytes: 256,
            parity_ratio: 0.1,
            ..SenderConfig::default()
        };
        let sender = sender_for(shard, index.serialize(), &cfg);
        let out = tmp_dir("beyond");
        let mut rx = Receiver::new(&out);
        let mut ch = FaultyChannel::new(FaultPlan::loss(7, 0.5));
        sender.send_all(&mut ch).unwrap();
        rx.drain(&mut ch);
        match rx.finish() {
            Err(DistError::Incomplete { missing }) => assert!(missing > 0),
            other => panic!("expected Incomplete, got {other:?}"),
        }
        // nothing half-written: every committed file must verify
        if let Ok(data) = std::fs::read(out.join(shard_file_name(0))) {
            walk_shard(&data).unwrap();
        }
        assert!(!out.join(format!("{}.tmp", shard_file_name(0))).exists());
        let _ = std::fs::remove_dir_all(&out);
    }

    #[test]
    fn availability_publishes_per_unit_as_shards_commit() {
        // two shards: shard 0 holds layers 0..2, shard 1 holds layer 2 —
        // deliver shard 0 + index first, check partial availability,
        // then shard 1.
        let shard0 = synth_shard(0, 3, 1500, 51);
        let shard1 = synth_shard(1, 1, 1500, 52);
        let mut index = index_for_shard(&shard0);
        let rec1 = walk_shard(&shard1).unwrap();
        index.n_shards = 2;
        index.entries.push(crate::codec::container::IndexEntry {
            name: "t3".into(),
            rows: 1,
            cols: rec1[0].0.n_elem,
            layer: 3,
            block_type: 1,
            codec: rec1[0].0.codec,
            format: rec1[0].0.format,
            shard: 1,
            offset: (rec1[0].1.start - RECORD_HEADER_BYTES) as u64,
            len: RECORD_HEADER_BYTES as u64 + rec1[0].0.payload_len,
            payload_crc: rec1[0].0.payload_crc,
        });
        let cfg = SenderConfig {
            block_bytes: 2048,
            symbol_bytes: 256,
            ..SenderConfig::default()
        };
        let s0 = Sender::from_parts("synth", vec![(0u16, shard0)], &cfg).unwrap();
        let s1 = Sender::from_parts("synth", vec![(1u16, shard1)], &cfg).unwrap();
        let si = Sender::from_parts(
            "synth",
            vec![(STREAM_INDEX, index.serialize())],
            &cfg,
        )
        .unwrap();
        // one combined manifest so the receiver knows all three streams
        let manifest = Manifest {
            model: "synth".into(),
            streams: s0
                .manifest()
                .streams
                .iter()
                .chain(s1.manifest().streams.iter())
                .chain(si.manifest().streams.iter())
                .cloned()
                .collect(),
        };

        let map = Arc::new(AvailabilityMap::for_layers(4));
        let out = tmp_dir("avail");
        let mut rx = Receiver::new(&out);
        rx.set_availability(Arc::clone(&map));
        let mut ch = LosslessChannel::default();

        // manifest + shard 0 + index, but not shard 1
        let h = PacketHeader {
            fec: FecId::NoCode.as_u8(),
            flags: crate::distribution::sender::FLAG_CONTROL,
            stream: STREAM_MANIFEST,
            block: 0,
            symbol: 0,
            k: 1,
            parity: 0,
            symbol_bytes: manifest.encode().len() as u32,
            block_bytes: manifest.encode().len() as u32,
            block_offset: 0,
        };
        ch.send(&crate::distribution::sender::encode_packet(&h, &manifest.encode()));
        let wanted0: Vec<(u16, u32)> = s0.stream_plans().flat_map(|p| {
            let s = p.stream;
            p.blocks.iter().map(move |b| (s, b.block))
        }).collect();
        s0.send_blocks(&mut ch, &wanted0).unwrap();
        let wanted_i: Vec<(u16, u32)> = si.stream_plans().flat_map(|p| {
            let s = p.stream;
            p.blocks.iter().map(move |b| (s, b.block))
        }).collect();
        si.send_blocks(&mut ch, &wanted_i).unwrap();
        rx.drain(&mut ch);

        assert!(!rx.is_complete());
        // layers 0..=2 (units 1..=3) live in shard 0: servable now
        assert!(map.is_ready(1) && map.is_ready(2) && map.is_ready(3));
        // layer 3 (unit 4) lives in shard 1: not yet
        assert!(!map.is_ready(4));
        // embedding/head units wait on no shard at all here
        assert!(map.is_ready(0));

        let wanted1: Vec<(u16, u32)> = s1.stream_plans().flat_map(|p| {
            let s = p.stream;
            p.blocks.iter().map(move |b| (s, b.block))
        }).collect();
        s1.send_blocks(&mut ch, &wanted1).unwrap();
        rx.drain(&mut ch);
        assert!(rx.is_complete());
        assert!(map.is_ready(4));
        let _ = std::fs::remove_dir_all(&out);
    }

    #[test]
    fn forged_consistent_packet_fails_record_verification() {
        // a wrong-but-CRC-valid packet: sender re-framed with altered
        // payload — block reassembles, but walk_shard catches it
        let shard = synth_shard(0, 2, 1000, 77);
        let index = index_for_shard(&shard);
        let cfg = SenderConfig {
            block_bytes: 1 << 20, // one block
            symbol_bytes: 256,
            fec: FecId::NoCode,
            ..SenderConfig::default()
        };
        let sender = sender_for(shard, index.serialize(), &cfg);
        let out = tmp_dir("forged");
        let mut rx = Receiver::new(&out);
        let mut ch = LosslessChannel::default();
        sender.send_all(&mut ch).unwrap();
        let mut saw_corrupt = false;
        while let Some(frame) = ch.recv() {
            let (h, payload) = parse_packet(&frame).unwrap();
            if !h.is_control() && h.stream == 0 && h.block == 0 && h.symbol == 1 {
                // forge: flip a payload byte and re-seal the frame CRC
                let mut p = payload.to_vec();
                p[10] ^= 0xFF;
                let forged = crate::distribution::sender::encode_packet(&h, &p);
                let _ = rx.ingest(&forged);
            } else {
                match rx.ingest(&frame) {
                    Ok(()) => {}
                    Err(DistError::RecordCorrupt { .. }) => saw_corrupt = true,
                    Err(e) => panic!("unexpected error {e}"),
                }
            }
        }
        assert!(saw_corrupt, "forged payload must fail record verification");
        assert!(
            !out.join(shard_file_name(0)).exists(),
            "corrupt shard must never commit"
        );
        let _ = std::fs::remove_dir_all(&out);
    }
}
