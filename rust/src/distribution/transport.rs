//! The packet channel abstraction and the seeded fault-injection
//! channel the robustness sweep runs on.
//!
//! [`FaultyChannel`] is deterministic: the same [`FaultPlan`] (seed
//! included) applied to the same send sequence produces the same
//! delivered packet sequence, so every loss/corruption scenario in the
//! tests, the `distribute-sim` CLI, and the Python verify port replays
//! bit-for-bit. Fault draw order is part of the contract: each `send`
//! draws exactly four uniforms — drop, duplicate, bit-flip, truncate, in
//! that order — then conditional draws for flip position/bit, truncate
//! length, and reorder insertion. Keep `sim_distribution.py` in sync
//! when changing it.

use crate::util::prng::Xoshiro256;
use std::collections::VecDeque;
use std::net::{SocketAddr, ToSocketAddrs, UdpSocket};
use std::time::Duration;

/// Where packets go. In-process for the sim/bench/tests; the trait is
/// the seam a real datagram socket would implement.
pub trait Transport {
    /// Queue one packet (the channel may drop/corrupt/duplicate it).
    fn send(&mut self, packet: &[u8]);

    /// Pull the next delivered packet, `None` when drained.
    fn recv(&mut self) -> Option<Vec<u8>>;
}

/// A lossless in-order channel (the control case).
#[derive(Default)]
pub struct LosslessChannel {
    queue: VecDeque<Vec<u8>>,
    pub stats: TransportStats,
}

impl Transport for LosslessChannel {
    fn send(&mut self, packet: &[u8]) {
        self.stats.sent += 1;
        self.stats.delivered += 1;
        self.queue.push_back(packet.to_vec());
    }

    fn recv(&mut self) -> Option<Vec<u8>> {
        self.queue.pop_front()
    }
}

/// Deterministic fault model for one channel instance.
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    pub seed: u64,
    /// probability a sent packet is dropped (burst trigger included)
    pub drop_rate: f64,
    /// when a drop triggers, this many *further* consecutive packets are
    /// also dropped (0 = independent losses)
    pub burst_len: u32,
    /// probability a delivered packet is delivered twice
    pub dup_rate: f64,
    /// probability one bit of a delivered packet is flipped
    pub flip_rate: f64,
    /// probability a delivered packet is truncated to a random prefix
    pub truncate_rate: f64,
    /// delivered packets may be inserted up to this many slots before
    /// the queue tail (0 = strictly in order)
    pub reorder_window: usize,
}

impl FaultPlan {
    /// No faults at all (still deterministic).
    pub fn clean(seed: u64) -> Self {
        Self {
            seed,
            drop_rate: 0.0,
            burst_len: 0,
            dup_rate: 0.0,
            flip_rate: 0.0,
            truncate_rate: 0.0,
            reorder_window: 0,
        }
    }

    /// Pure random loss at `rate`, everything else clean.
    pub fn loss(seed: u64, rate: f64) -> Self {
        Self {
            drop_rate: rate,
            ..Self::clean(seed)
        }
    }

    /// The full gauntlet the fault sweep uses: loss + bursts + reorder +
    /// duplication + corruption + truncation.
    pub fn gauntlet(seed: u64, rate: f64) -> Self {
        Self {
            seed,
            drop_rate: rate,
            burst_len: 2,
            dup_rate: 0.05,
            flip_rate: 0.02,
            truncate_rate: 0.02,
            reorder_window: 8,
        }
    }
}

/// What the channel did to the traffic — the sim report's loss ledger.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    pub sent: u64,
    pub delivered: u64,
    pub dropped: u64,
    pub duplicated: u64,
    pub corrupted: u64,
    pub truncated: u64,
    pub reordered: u64,
}

/// The seeded lossy channel: applies the [`FaultPlan`] to every send.
pub struct FaultyChannel {
    plan: FaultPlan,
    rng: Xoshiro256,
    queue: VecDeque<Vec<u8>>,
    burst_left: u32,
    pub stats: TransportStats,
}

impl FaultyChannel {
    pub fn new(plan: FaultPlan) -> Self {
        Self {
            plan,
            rng: Xoshiro256::seed_from_u64(plan.seed),
            queue: VecDeque::new(),
            burst_left: 0,
            stats: TransportStats::default(),
        }
    }

    fn deliver(&mut self, packet: Vec<u8>) {
        let len = self.queue.len();
        let pos = if self.plan.reorder_window > 0 && len > 0 {
            let w = self.plan.reorder_window.min(len);
            let back = self.rng.next_below(w as u64 + 1) as usize;
            if back > 0 {
                self.stats.reordered += 1;
            }
            len - back
        } else {
            len
        };
        self.queue.insert(pos, packet);
        self.stats.delivered += 1;
    }
}

impl Transport for FaultyChannel {
    fn send(&mut self, packet: &[u8]) {
        self.stats.sent += 1;
        // fixed draw order (see module docs): every send consumes these
        // four uniforms whether or not each fault fires
        let r_drop = self.rng.next_f64();
        let r_dup = self.rng.next_f64();
        let r_flip = self.rng.next_f64();
        let r_trunc = self.rng.next_f64();
        if self.burst_left > 0 {
            self.burst_left -= 1;
            self.stats.dropped += 1;
            return;
        }
        if r_drop < self.plan.drop_rate {
            self.burst_left = self.plan.burst_len;
            self.stats.dropped += 1;
            return;
        }
        let mut pkt = packet.to_vec();
        if r_flip < self.plan.flip_rate && !pkt.is_empty() {
            let pos = self.rng.next_below(pkt.len() as u64) as usize;
            let bit = self.rng.next_below(8) as u32;
            pkt[pos] ^= 1 << bit;
            self.stats.corrupted += 1;
        }
        if r_trunc < self.plan.truncate_rate && !pkt.is_empty() {
            let keep = self.rng.next_below(pkt.len() as u64) as usize;
            pkt.truncate(keep);
            self.stats.truncated += 1;
        }
        let dup = r_dup < self.plan.dup_rate;
        if dup {
            self.stats.duplicated += 1;
            self.deliver(pkt.clone());
        }
        self.deliver(pkt);
    }

    fn recv(&mut self) -> Option<Vec<u8>> {
        self.queue.pop_front()
    }
}

/// Largest payload one UDP datagram can carry (65535 minus the 8-byte
/// UDP and 20-byte IPv4 headers).
pub const UDP_MAX_PAYLOAD: usize = 65_507;

/// A real datagram socket behind the same [`Transport`] seam the sim
/// channels implement — `std::net::UdpSocket` only, no new crates. UDP
/// already matches the trait's loss model (datagrams may be dropped,
/// duplicated, or reordered in flight; the FEC layer above is what makes
/// that survivable), so `send` is fire-and-forget and `recv` maps a
/// receive timeout to `None` ("drained for now") instead of blocking
/// forever.
///
/// [`FaultyChannel`] stays the CI tier: it is deterministic and needs no
/// network. `UdpTransport` is the deployment tier the CLI's sender and
/// receiver run on when two processes stream a model for real.
pub struct UdpTransport {
    socket: UdpSocket,
    peer: SocketAddr,
    /// reusable receive buffer sized for the largest possible datagram
    buf: Vec<u8>,
    pub stats: TransportStats,
}

impl UdpTransport {
    /// Bind `local` (e.g. `"127.0.0.1:0"`) and aim `send` at `peer`.
    /// `recv` waits at most `recv_timeout` before reporting the socket
    /// drained.
    pub fn bind<A: ToSocketAddrs, B: ToSocketAddrs>(
        local: A,
        peer: B,
        recv_timeout: Duration,
    ) -> std::io::Result<Self> {
        let socket = UdpSocket::bind(local)?;
        // a zero Duration means "block forever" to set_read_timeout —
        // clamp up so the trait's non-blocking drain contract holds
        socket.set_read_timeout(Some(recv_timeout.max(Duration::from_millis(1))))?;
        let peer = peer
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidInput, "empty peer"))?;
        Ok(Self {
            socket,
            peer,
            buf: vec![0u8; UDP_MAX_PAYLOAD],
            stats: TransportStats::default(),
        })
    }

    /// The bound local address (port 0 resolves at bind time).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.socket.local_addr()
    }
}

impl Transport for UdpTransport {
    fn send(&mut self, packet: &[u8]) {
        self.stats.sent += 1;
        // fire-and-forget: an oversized or unroutable datagram counts
        // as dropped, exactly like the lossy sim channel
        match self.socket.send_to(packet, self.peer) {
            Ok(_) => self.stats.delivered += 1,
            Err(_) => self.stats.dropped += 1,
        }
    }

    fn recv(&mut self) -> Option<Vec<u8>> {
        match self.socket.recv_from(&mut self.buf) {
            Ok((n, _)) => Some(self.buf[..n].to_vec()),
            // WouldBlock (unix) / TimedOut (windows) both mean "nothing
            // arrived within the timeout"
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                None
            }
            Err(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkts(n: usize) -> Vec<Vec<u8>> {
        (0..n).map(|i| vec![(i % 251) as u8; 64]).collect()
    }

    fn drain(t: &mut impl Transport) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        while let Some(p) = t.recv() {
            out.push(p);
        }
        out
    }

    #[test]
    fn clean_plan_is_lossless_in_order() {
        let mut ch = FaultyChannel::new(FaultPlan::clean(1));
        let sent = pkts(50);
        for p in &sent {
            ch.send(p);
        }
        assert_eq!(drain(&mut ch), sent);
        assert_eq!(ch.stats.dropped, 0);
        assert_eq!(ch.stats.delivered, 50);
    }

    #[test]
    fn same_seed_same_fault_sequence() {
        let plan = FaultPlan::gauntlet(77, 0.2);
        let mut a = FaultyChannel::new(plan);
        let mut b = FaultyChannel::new(plan);
        for p in pkts(200) {
            a.send(&p);
            b.send(&p);
        }
        assert_eq!(a.stats, b.stats);
        assert_eq!(drain(&mut a), drain(&mut b));
    }

    #[test]
    fn drop_rate_is_roughly_honored() {
        let mut ch = FaultyChannel::new(FaultPlan::loss(5, 0.3));
        for p in pkts(2000) {
            ch.send(&p);
        }
        let frac = ch.stats.dropped as f64 / ch.stats.sent as f64;
        assert!((0.2..0.4).contains(&frac), "drop fraction {frac}");
        assert_eq!(ch.stats.delivered + ch.stats.dropped, ch.stats.sent);
    }

    #[test]
    fn burst_drops_consecutive_packets() {
        let plan = FaultPlan {
            burst_len: 3,
            ..FaultPlan::loss(9, 0.05)
        };
        let mut ch = FaultyChannel::new(plan);
        for p in pkts(1000) {
            ch.send(&p);
        }
        // every trigger costs 1 + up to burst_len packets, so the total
        // drop fraction must exceed the trigger rate alone
        let frac = ch.stats.dropped as f64 / ch.stats.sent as f64;
        assert!(frac > 0.08, "burst amplification missing: {frac}");
    }

    #[test]
    fn udp_loopback_roundtrips_packets() {
        let timeout = Duration::from_millis(200);
        // receiver first (its peer is never used), then a sender aimed
        // at the receiver's ephemeral port
        let mut a = UdpTransport::bind("127.0.0.1:0", "127.0.0.1:9", timeout).unwrap();
        let mut b =
            UdpTransport::bind("127.0.0.1:0", a.local_addr().unwrap(), timeout).unwrap();

        let sent = pkts(20);
        for p in &sent {
            b.send(p);
        }
        assert_eq!(b.stats.sent, 20);
        let mut got = Vec::new();
        while let Some(p) = a.recv() {
            got.push(p);
        }
        // loopback UDP is reliable in practice; tolerate kernel-side
        // drops but require the common case to hold
        assert!(!got.is_empty(), "nothing arrived over loopback");
        for p in &got {
            assert!(sent.contains(p), "payload corrupted in flight");
        }
    }

    #[test]
    fn udp_recv_times_out_to_none() {
        let mut t =
            UdpTransport::bind("127.0.0.1:0", "127.0.0.1:9", Duration::from_millis(20)).unwrap();
        let start = std::time::Instant::now();
        assert!(t.recv().is_none(), "idle socket must drain to None");
        assert!(start.elapsed() < Duration::from_secs(5), "timeout honored");
    }

    #[test]
    fn faults_are_counted_and_bounded() {
        let mut ch = FaultyChannel::new(FaultPlan::gauntlet(13, 0.1));
        let sent = pkts(500);
        for p in &sent {
            ch.send(p);
        }
        let got = drain(&mut ch);
        assert_eq!(got.len() as u64, ch.stats.delivered);
        assert!(ch.stats.corrupted > 0);
        assert!(ch.stats.duplicated > 0);
        assert!(ch.stats.reordered > 0);
        // a duplicated packet adds a delivery beyond the sends
        assert_eq!(
            ch.stats.delivered,
            ch.stats.sent - ch.stats.dropped + ch.stats.duplicated
        );
    }
}
