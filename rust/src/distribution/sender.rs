//! The sending side: packet wire format, transfer manifest, the
//! record-aligned block planner, and the [`Sender`] that pumps a packed
//! model directory through a [`Transport`].
//!
//! ## Packet layout (40-byte header, little-endian)
//!
//! ```text
//! off  size  field
//!   0     4  magic "ECP8"
//!   4     1  version (1)
//!   5     1  fec id (FecId byte)
//!   6     2  flags (bit 0 = control packet, payload is the manifest)
//!   8     2  stream (shard index; 0xFFFF = index file, 0xFFFE = manifest)
//!  10     4  block (block number within the stream)
//!  14     2  symbol (0..k = source, k..n = parity)
//!  16     2  k       (source symbols in this block)
//!  18     2  parity  (repair symbols in this block)
//!  20     4  symbol_bytes
//!  24     4  block_bytes  (true pre-padding byte length of the block)
//!  28     8  block_offset (byte offset of the block within its file)
//!  36     4  reserved (0)
//!  40     …  payload (symbol_bytes bytes)
//!   +     4  crc32 over header + payload
//! ```
//!
//! Every packet is self-describing: the receiver needs no out-of-band
//! geometry, so packets survive arbitrary reordering and loss. Block
//! boundaries never split a container record, so any subset of decoded
//! blocks yields whole CRC-verifiable records — that is what makes
//! partial availability servable.

use super::fec::{fec_for, FecId, FecParams};
use super::transport::Transport;
use super::DistError;
use crate::codec::container::{shard_file_name, walk_shard, TensorIndex, INDEX_FILE};
use crate::util::crc32::crc32;
use std::path::Path;

pub const PACKET_MAGIC: &[u8; 4] = b"ECP8";
pub const PACKET_VERSION: u8 = 1;
pub const PACKET_HEADER_BYTES: usize = 40;
/// flags bit 0: control packet (payload is the serialized [`Manifest`])
pub const FLAG_CONTROL: u16 = 1;
/// pseudo-stream id of the index file
pub const STREAM_INDEX: u16 = 0xFFFF;
/// pseudo-stream id of manifest control packets
pub const STREAM_MANIFEST: u16 = 0xFFFE;
/// manifest copies per send pass (control packets get no parity, so
/// repetition is their loss protection)
pub const MANIFEST_COPIES: usize = 3;

pub const DEFAULT_BLOCK_BYTES: usize = 64 * 1024;
pub const DEFAULT_SYMBOL_BYTES: u32 = 1024;
/// cap on source symbols per block; longer blocks widen the symbol
/// instead, keeping the decode matrix small
pub const MAX_SOURCE_SYMBOLS: usize = 64;

/// Parsed packet header (see the module docs for the wire layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PacketHeader {
    pub fec: u8,
    pub flags: u16,
    pub stream: u16,
    pub block: u32,
    pub symbol: u16,
    pub k: u16,
    pub parity: u16,
    pub symbol_bytes: u32,
    pub block_bytes: u32,
    pub block_offset: u64,
}

impl PacketHeader {
    pub fn is_control(&self) -> bool {
        self.flags & FLAG_CONTROL != 0
    }

    pub fn params(&self) -> Result<FecParams, DistError> {
        let fec = FecId::from_u8(self.fec).ok_or(DistError::UnknownFec(self.fec))?;
        let p = FecParams {
            fec,
            k: self.k,
            parity: self.parity,
            symbol_bytes: self.symbol_bytes,
        };
        p.validate()?;
        Ok(p)
    }
}

/// Frame one packet: header + payload + trailing crc32.
pub fn encode_packet(h: &PacketHeader, payload: &[u8]) -> Vec<u8> {
    assert_eq!(
        payload.len(),
        h.symbol_bytes as usize,
        "payload must be exactly one symbol"
    );
    let mut out = Vec::with_capacity(PACKET_HEADER_BYTES + payload.len() + 4);
    out.extend_from_slice(PACKET_MAGIC);
    out.push(PACKET_VERSION);
    out.push(h.fec);
    out.extend_from_slice(&h.flags.to_le_bytes());
    out.extend_from_slice(&h.stream.to_le_bytes());
    out.extend_from_slice(&h.block.to_le_bytes());
    out.extend_from_slice(&h.symbol.to_le_bytes());
    out.extend_from_slice(&h.k.to_le_bytes());
    out.extend_from_slice(&h.parity.to_le_bytes());
    out.extend_from_slice(&h.symbol_bytes.to_le_bytes());
    out.extend_from_slice(&h.block_bytes.to_le_bytes());
    out.extend_from_slice(&h.block_offset.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    out.extend_from_slice(payload);
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Parse + verify one received frame. Every malformed input — wrong
/// magic, truncation anywhere, flipped bit, impossible geometry — maps
/// to a structured [`DistError`]; this function must never panic on
/// attacker- or fault-controlled bytes.
pub fn parse_packet(data: &[u8]) -> Result<(PacketHeader, &[u8]), DistError> {
    let min = PACKET_HEADER_BYTES + 4;
    if data.len() < min {
        return Err(DistError::Truncated {
            need: min,
            have: data.len(),
        });
    }
    if &data[0..4] != PACKET_MAGIC {
        return Err(DistError::BadMagic);
    }
    if data[4] != PACKET_VERSION {
        return Err(DistError::BadVersion(data[4]));
    }
    let u16_at = |o: usize| u16::from_le_bytes([data[o], data[o + 1]]);
    let u32_at = |o: usize| u32::from_le_bytes([data[o], data[o + 1], data[o + 2], data[o + 3]]);
    let h = PacketHeader {
        fec: data[5],
        flags: u16_at(6),
        stream: u16_at(8),
        block: u32_at(10),
        symbol: u16_at(14),
        k: u16_at(16),
        parity: u16_at(18),
        symbol_bytes: u32_at(20),
        block_bytes: u32_at(24),
        block_offset: u64::from_le_bytes(data[28..36].try_into().expect("8 bytes")),
    };
    let need = PACKET_HEADER_BYTES
        .checked_add(h.symbol_bytes as usize)
        .and_then(|v| v.checked_add(4))
        .ok_or(DistError::BadParams("symbol_bytes overflows frame length"))?;
    if data.len() != need {
        return Err(DistError::Truncated {
            need,
            have: data.len(),
        });
    }
    let stored = u32_at(need - 4);
    let computed = crc32(&data[..need - 4]);
    if stored != computed {
        return Err(DistError::CrcMismatch { stored, computed });
    }
    let params = h.params()?;
    if !h.is_control() && (h.symbol as usize) >= params.n() {
        return Err(DistError::BadParams("symbol id out of range"));
    }
    Ok((h, &data[PACKET_HEADER_BYTES..need - 4]))
}

/// What one stream (file) looks like to the transfer: its pseudo-id,
/// true length, and block count. Stream ids `< 0xFFFE` are shard
/// indices; [`STREAM_INDEX`] is the binary tensor index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestStream {
    pub stream: u16,
    pub file_len: u64,
    pub n_blocks: u32,
}

/// The transfer manifest: which streams exist and how many blocks each
/// has — the receiver's completeness criterion. Carried in control
/// packets (already CRC-framed), repeated [`MANIFEST_COPIES`] times per
/// pass to survive loss.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub model: String,
    pub streams: Vec<ManifestStream>,
}

const MANIFEST_MAGIC: &[u8; 4] = b"ECM8";
const MANIFEST_VERSION: u8 = 1;

impl Manifest {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MANIFEST_MAGIC);
        out.push(MANIFEST_VERSION);
        let name = self.model.as_bytes();
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name);
        out.extend_from_slice(&(self.streams.len() as u16).to_le_bytes());
        for s in &self.streams {
            out.extend_from_slice(&s.stream.to_le_bytes());
            out.extend_from_slice(&s.file_len.to_le_bytes());
            out.extend_from_slice(&s.n_blocks.to_le_bytes());
        }
        out
    }

    pub fn decode(data: &[u8]) -> Result<Self, DistError> {
        let take = |pos: &mut usize, n: usize| -> Result<&[u8], DistError> {
            let end = pos.checked_add(n).ok_or(DistError::Truncated {
                need: usize::MAX,
                have: data.len(),
            })?;
            if end > data.len() {
                return Err(DistError::Truncated {
                    need: end,
                    have: data.len(),
                });
            }
            let s = &data[*pos..end];
            *pos = end;
            Ok(s)
        };
        let mut pos = 0usize;
        if take(&mut pos, 4)? != MANIFEST_MAGIC {
            return Err(DistError::BadMagic);
        }
        let ver = take(&mut pos, 1)?[0];
        if ver != MANIFEST_VERSION {
            return Err(DistError::BadVersion(ver));
        }
        let name_len = u16::from_le_bytes(take(&mut pos, 2)?.try_into().expect("2 bytes")) as usize;
        let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
            .map_err(|_| DistError::BadParams("manifest model name not utf-8"))?;
        let n_streams = u16::from_le_bytes(take(&mut pos, 2)?.try_into().expect("2 bytes"));
        let mut streams = Vec::with_capacity(n_streams as usize);
        for _ in 0..n_streams {
            let stream = u16::from_le_bytes(take(&mut pos, 2)?.try_into().expect("2 bytes"));
            let file_len = u64::from_le_bytes(take(&mut pos, 8)?.try_into().expect("8 bytes"));
            let n_blocks = u32::from_le_bytes(take(&mut pos, 4)?.try_into().expect("4 bytes"));
            streams.push(ManifestStream {
                stream,
                file_len,
                n_blocks,
            });
        }
        Ok(Manifest { model: name, streams })
    }
}

/// One source block: a record-aligned byte range of a stream plus its
/// negotiated FEC geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockPlan {
    pub block: u32,
    pub offset: u64,
    /// true byte length (pre-padding)
    pub len: u32,
    pub params: FecParams,
}

/// The block decomposition of one stream.
#[derive(Debug, Clone)]
pub struct StreamPlan {
    pub stream: u16,
    pub file_len: u64,
    pub blocks: Vec<BlockPlan>,
}

impl StreamPlan {
    fn manifest_entry(&self) -> ManifestStream {
        ManifestStream {
            stream: self.stream,
            file_len: self.file_len,
            n_blocks: self.blocks.len() as u32,
        }
    }
}

/// Sender tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct SenderConfig {
    pub fec: FecId,
    /// repair symbols as a fraction of k (clamped to at least 1 and to
    /// the GF(2⁸) ceiling); ignored for [`FecId::NoCode`]
    pub parity_ratio: f64,
    pub block_bytes: usize,
    pub symbol_bytes: u32,
}

impl Default for SenderConfig {
    fn default() -> Self {
        Self {
            fec: FecId::ReedSolomon8,
            parity_ratio: 0.25,
            block_bytes: DEFAULT_BLOCK_BYTES,
            symbol_bytes: DEFAULT_SYMBOL_BYTES,
        }
    }
}

impl SenderConfig {
    /// FEC geometry for one block of `len` bytes: start from the
    /// configured symbol width, widen (doubling) until the block fits in
    /// [`MAX_SOURCE_SYMBOLS`] source symbols, then fund parity from the
    /// ratio.
    pub(crate) fn params_for(&self, len: usize) -> Result<FecParams, DistError> {
        if len == 0 {
            return Err(DistError::BadParams("empty block"));
        }
        let mut sym = self.symbol_bytes.max(1) as usize;
        let mut k = len.div_ceil(sym);
        while k > MAX_SOURCE_SYMBOLS {
            sym *= 2;
            k = len.div_ceil(sym);
        }
        let parity = match self.fec {
            FecId::NoCode => 0,
            FecId::ReedSolomon8 => {
                let want = (k as f64 * self.parity_ratio).ceil() as usize;
                want.clamp(1, super::fec::MAX_TOTAL_SYMBOLS - k)
            }
        };
        let p = FecParams {
            fec: self.fec,
            k: k as u16,
            parity: parity as u16,
            symbol_bytes: sym as u32,
        };
        p.validate()?;
        Ok(p)
    }
}

/// Record-aligned block plan for a shard: the 8-byte shard header rides
/// with the first record, and each block closes at the first record
/// boundary at or past the target size. `walk_shard` has already
/// CRC-verified every record, so the sender never streams corrupt data.
pub(crate) fn plan_shard_blocks(
    stream: u16,
    data: &[u8],
    cfg: &SenderConfig,
) -> Result<StreamPlan, DistError> {
    let records = walk_shard(data).map_err(|e| DistError::Io(format!("source shard: {e}")))?;
    let mut blocks = Vec::new();
    let mut start = 0usize;
    for (i, (_, range)) in records.iter().enumerate() {
        let end = range.end;
        if end - start >= cfg.block_bytes || i == records.len() - 1 {
            blocks.push(BlockPlan {
                block: blocks.len() as u32,
                offset: start as u64,
                len: (end - start) as u32,
                params: cfg.params_for(end - start)?,
            });
            start = end;
        }
    }
    if start != data.len() {
        return Err(DistError::Io("shard has bytes past the last record".into()));
    }
    Ok(StreamPlan {
        stream,
        file_len: data.len() as u64,
        blocks,
    })
}

/// Plain chunked plan for non-record streams (the index file).
fn plan_plain_blocks(stream: u16, data: &[u8], cfg: &SenderConfig) -> Result<StreamPlan, DistError> {
    let mut blocks = Vec::new();
    let mut start = 0usize;
    while start < data.len() {
        let end = (start + cfg.block_bytes).min(data.len());
        blocks.push(BlockPlan {
            block: blocks.len() as u32,
            offset: start as u64,
            len: (end - start) as u32,
            params: cfg.params_for(end - start)?,
        });
        start = end;
    }
    Ok(StreamPlan {
        stream,
        file_len: data.len() as u64,
        blocks,
    })
}

/// Tally of one send pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct SendReport {
    pub packets: u64,
    pub source_packets: u64,
    pub parity_packets: u64,
    pub control_packets: u64,
    /// source bytes represented (true block lengths, no padding/parity)
    pub payload_bytes: u64,
    /// bytes handed to the transport, framing included
    pub wire_bytes: u64,
}

impl SendReport {
    /// Fold another pass (e.g. a retransmission round) into this tally.
    pub fn absorb(&mut self, other: SendReport) {
        self.packets += other.packets;
        self.source_packets += other.source_packets;
        self.parity_packets += other.parity_packets;
        self.control_packets += other.control_packets;
        self.payload_bytes += other.payload_bytes;
        self.wire_bytes += other.wire_bytes;
    }
}

/// The sending half of a transfer: holds every stream's bytes and block
/// plan, pumps packets into a [`Transport`], and can re-emit any subset
/// of blocks for retransmission rounds.
pub struct Sender {
    manifest: Manifest,
    streams: Vec<(StreamPlan, Vec<u8>)>,
}

impl Sender {
    /// Build a sender over a packed model directory (v2/v3 layout:
    /// `index.ecf8i` + `shard-NNNN.ecf8s`).
    pub fn from_dir(dir: &Path, cfg: &SenderConfig) -> Result<Self, DistError> {
        let index_bytes = std::fs::read(dir.join(INDEX_FILE))?;
        let index = TensorIndex::deserialize(&index_bytes)
            .map_err(|e| DistError::Io(format!("source index: {e}")))?;
        let mut streams = Vec::new();
        for s in 0..index.n_shards {
            let data = std::fs::read(dir.join(shard_file_name(s)))?;
            streams.push((s as u16, data));
        }
        streams.push((STREAM_INDEX, index_bytes));
        Self::from_parts(&index.model, streams, cfg)
    }

    /// Build a sender from in-memory streams (shards by index plus the
    /// [`STREAM_INDEX`] pseudo-stream). Shard streams are planned
    /// record-aligned; everything else is chunked plainly.
    pub fn from_parts(
        model: &str,
        streams: Vec<(u16, Vec<u8>)>,
        cfg: &SenderConfig,
    ) -> Result<Self, DistError> {
        let mut planned = Vec::with_capacity(streams.len());
        for (stream, data) in streams {
            let plan = if stream < STREAM_MANIFEST {
                plan_shard_blocks(stream, &data, cfg)?
            } else {
                plan_plain_blocks(stream, &data, cfg)?
            };
            planned.push((plan, data));
        }
        let manifest = Manifest {
            model: model.to_string(),
            streams: planned.iter().map(|(p, _)| p.manifest_entry()).collect(),
        };
        Ok(Self {
            manifest,
            streams: planned,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stream_plans(&self) -> impl Iterator<Item = &StreamPlan> {
        self.streams.iter().map(|(p, _)| p)
    }

    /// Total packets one full pass emits (manifest copies included).
    pub fn packets_per_pass(&self) -> u64 {
        let data: u64 = self
            .streams
            .iter()
            .flat_map(|(p, _)| &p.blocks)
            .map(|b| b.params.n() as u64)
            .sum();
        data + MANIFEST_COPIES as u64
    }

    fn send_manifest(&self, t: &mut dyn Transport, report: &mut SendReport) {
        let payload = self.manifest.encode();
        let h = PacketHeader {
            fec: FecId::NoCode.as_u8(),
            flags: FLAG_CONTROL,
            stream: STREAM_MANIFEST,
            block: 0,
            symbol: 0,
            k: 1,
            parity: 0,
            symbol_bytes: payload.len() as u32,
            block_bytes: payload.len() as u32,
            block_offset: 0,
        };
        for _ in 0..MANIFEST_COPIES {
            let pkt = encode_packet(&h, &payload);
            report.control_packets += 1;
            report.packets += 1;
            report.wire_bytes += pkt.len() as u64;
            t.send(&pkt);
        }
    }

    fn send_block(
        &self,
        t: &mut dyn Transport,
        plan: &StreamPlan,
        data: &[u8],
        b: &BlockPlan,
        report: &mut SendReport,
    ) -> Result<(), DistError> {
        let params = b.params;
        let (k, sym) = (params.k as usize, params.symbol_bytes as usize);
        let raw = &data[b.offset as usize..(b.offset + b.len as u64) as usize];
        let mut source: Vec<Vec<u8>> = Vec::with_capacity(k);
        for i in 0..k {
            let lo = i * sym;
            let hi = ((i + 1) * sym).min(raw.len());
            let mut s = raw[lo.min(raw.len())..hi].to_vec();
            s.resize(sym, 0);
            source.push(s);
        }
        let codec = fec_for(params.fec.as_u8()).ok_or(DistError::UnknownFec(params.fec.as_u8()))?;
        let parity = codec.encode_parity(&params, &source)?;
        let mut h = PacketHeader {
            fec: params.fec.as_u8(),
            flags: 0,
            stream: plan.stream,
            block: b.block,
            symbol: 0,
            k: params.k,
            parity: params.parity,
            symbol_bytes: params.symbol_bytes,
            block_bytes: b.len,
            block_offset: b.offset,
        };
        for (i, s) in source.iter().chain(parity.iter()).enumerate() {
            h.symbol = i as u16;
            let pkt = encode_packet(&h, s);
            report.packets += 1;
            if i < k {
                report.source_packets += 1;
            } else {
                report.parity_packets += 1;
            }
            report.wire_bytes += pkt.len() as u64;
            t.send(&pkt);
        }
        report.payload_bytes += b.len as u64;
        Ok(())
    }

    /// One full pass: manifest copies, then every block of every stream.
    pub fn send_all(&self, t: &mut dyn Transport) -> Result<SendReport, DistError> {
        let mut report = SendReport::default();
        self.send_manifest(t, &mut report);
        for (plan, data) in &self.streams {
            for b in &plan.blocks {
                self.send_block(t, plan, data, b, &mut report)?;
            }
        }
        Ok(report)
    }

    /// Retransmission round: re-emit exactly the requested blocks (the
    /// receiver's `missing_blocks` list). A request for
    /// `(STREAM_MANIFEST, 0)` re-sends the manifest copies.
    pub fn send_blocks(
        &self,
        t: &mut dyn Transport,
        wanted: &[(u16, u32)],
    ) -> Result<SendReport, DistError> {
        let mut report = SendReport::default();
        for &(stream, block) in wanted {
            if stream == STREAM_MANIFEST {
                self.send_manifest(t, &mut report);
                continue;
            }
            let (plan, data) = self
                .streams
                .iter()
                .find(|(p, _)| p.stream == stream)
                .ok_or(DistError::BadParams("retransmit for unknown stream"))?;
            let b = plan
                .blocks
                .get(block as usize)
                .ok_or(DistError::BadParams("retransmit for unknown block"))?;
            self.send_block(t, plan, data, b, &mut report)?;
        }
        Ok(report)
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    /// A tiny well-formed shard: header + `n` records of `payload_len`
    /// pseudo-random payload bytes each.
    pub(crate) fn synth_shard(shard_index: u16, n: usize, payload_len: usize, seed: u64) -> Vec<u8> {
        use crate::codec::container::{RecordHeader, SHARD_MAGIC, V2_VERSION};
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut out = Vec::new();
        out.extend_from_slice(SHARD_MAGIC);
        out.extend_from_slice(&V2_VERSION.to_le_bytes());
        out.extend_from_slice(&shard_index.to_le_bytes());
        for _ in 0..n {
            let payload: Vec<u8> = (0..payload_len).map(|_| rng.next_u64() as u8).collect();
            let head = RecordHeader {
                codec: 1,
                format: 0,
                n_elem: payload_len as u64,
                payload_len: payload.len() as u64,
                payload_crc: crc32(&payload),
            };
            head.write_into(&mut out).unwrap();
            out.extend_from_slice(&payload);
        }
        out
    }

    #[test]
    fn packet_roundtrip_is_exact() {
        let h = PacketHeader {
            fec: 1,
            flags: 0,
            stream: 3,
            block: 9,
            symbol: 2,
            k: 4,
            parity: 2,
            symbol_bytes: 32,
            block_bytes: 100,
            block_offset: 4096,
        };
        let payload: Vec<u8> = (0..32).collect();
        let pkt = encode_packet(&h, &payload);
        assert_eq!(pkt.len(), PACKET_HEADER_BYTES + 32 + 4);
        let (got, body) = parse_packet(&pkt).unwrap();
        assert_eq!(got, h);
        assert_eq!(body, &payload[..]);
    }

    #[test]
    fn parse_rejects_malformed_frames_structurally() {
        let h = PacketHeader {
            fec: 1,
            flags: 0,
            stream: 0,
            block: 0,
            symbol: 0,
            k: 2,
            parity: 1,
            symbol_bytes: 16,
            block_bytes: 20,
            block_offset: 0,
        };
        let good = encode_packet(&h, &[7u8; 16]);

        assert!(matches!(
            parse_packet(&good[..10]),
            Err(DistError::Truncated { .. })
        ));
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(parse_packet(&bad), Err(DistError::BadMagic)));
        let mut bad = good.clone();
        bad[4] = 9;
        assert!(matches!(parse_packet(&bad), Err(DistError::BadVersion(9))));
        let mut bad = good.clone();
        *bad.last_mut().unwrap() ^= 0xFF;
        assert!(matches!(parse_packet(&bad), Err(DistError::CrcMismatch { .. })));
        let mut bad = good.clone();
        bad.truncate(good.len() - 3);
        assert!(matches!(parse_packet(&bad), Err(DistError::Truncated { .. })));
    }

    #[test]
    fn fuzzed_headers_never_panic() {
        // Corrupt every single byte of a valid frame (and re-seal the
        // CRC for header positions) — parse must return Ok or a
        // structured error, never panic. This covers impossible k/n,
        // out-of-range symbol ids, unknown fec ids, and length lies.
        let h = PacketHeader {
            fec: 1,
            flags: 0,
            stream: 1,
            block: 2,
            symbol: 1,
            k: 3,
            parity: 2,
            symbol_bytes: 8,
            block_bytes: 24,
            block_offset: 64,
        };
        let good = encode_packet(&h, &[1u8; 8]);
        for pos in 0..good.len() {
            for bit in 0..8 {
                let mut fuzz = good.clone();
                fuzz[pos] ^= 1 << bit;
                let _ = parse_packet(&fuzz); // must not panic
                // …and with a re-sealed CRC so header parsing runs
                let n = fuzz.len();
                let crc = crc32(&fuzz[..n - 4]);
                fuzz[n - 4..].copy_from_slice(&crc.to_le_bytes());
                let _ = parse_packet(&fuzz);
            }
        }
        // random garbage of assorted lengths
        let mut rng = Xoshiro256::seed_from_u64(99);
        for len in [0usize, 1, 4, 43, 44, 45, 100, 4096] {
            let junk: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let _ = parse_packet(&junk);
        }
    }

    #[test]
    fn manifest_roundtrip_and_truncation() {
        let m = Manifest {
            model: "tiny-llm-7m".into(),
            streams: vec![
                ManifestStream {
                    stream: 0,
                    file_len: 1234,
                    n_blocks: 3,
                },
                ManifestStream {
                    stream: STREAM_INDEX,
                    file_len: 99,
                    n_blocks: 1,
                },
            ],
        };
        let bytes = m.encode();
        assert_eq!(Manifest::decode(&bytes).unwrap(), m);
        for cut in 0..bytes.len() {
            assert!(
                Manifest::decode(&bytes[..cut]).is_err(),
                "prefix of {cut} bytes decoded"
            );
        }
    }

    #[test]
    fn shard_blocks_are_record_aligned() {
        let shard = synth_shard(0, 10, 3000, 42);
        let cfg = SenderConfig {
            block_bytes: 8 * 1024,
            ..SenderConfig::default()
        };
        let plan = plan_shard_blocks(0, &shard, &cfg).unwrap();
        assert!(plan.blocks.len() > 1, "want multiple blocks");
        let records = walk_shard(&shard).unwrap();
        let boundaries: Vec<u64> = records.iter().map(|(_, r)| r.end as u64).collect();
        let mut covered = 0u64;
        for b in &plan.blocks {
            assert_eq!(b.offset, covered, "blocks must tile the stream");
            covered += b.len as u64;
            assert!(
                boundaries.contains(&covered),
                "block end {covered} splits a record"
            );
            b.params.validate().unwrap();
        }
        assert_eq!(covered, shard.len() as u64);
    }

    #[test]
    fn params_widen_symbols_for_large_blocks() {
        let cfg = SenderConfig::default();
        let p = cfg.params_for(1024 * 1024).unwrap();
        assert!(p.k as usize <= MAX_SOURCE_SYMBOLS);
        assert!(p.parity >= 1);
        assert!((p.k as usize + p.parity as usize) <= 255);
        assert!(p.k as u64 * p.symbol_bytes as u64 >= 1024 * 1024);
    }

    #[test]
    fn send_all_emits_every_symbol_once() {
        let shard = synth_shard(0, 4, 500, 7);
        let cfg = SenderConfig {
            block_bytes: 1024,
            symbol_bytes: 128,
            ..SenderConfig::default()
        };
        let sender =
            Sender::from_parts("m", vec![(0u16, shard), (STREAM_INDEX, vec![9u8; 300])], &cfg)
                .unwrap();
        let mut ch = crate::distribution::transport::LosslessChannel::default();
        let report = sender.send_all(&mut ch).unwrap();
        assert_eq!(report.packets, sender.packets_per_pass());
        assert_eq!(report.control_packets, MANIFEST_COPIES as u64);
        let mut seen = std::collections::HashSet::new();
        let mut manifests = 0;
        while let Some(pkt) = ch.recv() {
            let (h, _) = parse_packet(&pkt).unwrap();
            if h.is_control() {
                manifests += 1;
                assert!(Manifest::decode(parse_packet(&pkt).unwrap().1).is_ok());
            } else {
                assert!(seen.insert((h.stream, h.block, h.symbol)), "dup symbol");
            }
        }
        assert_eq!(manifests, MANIFEST_COPIES);
    }
}
