//! Fleet shard distribution: FEC-protected streaming of container-v2
//! artifacts over lossy transports, with serve-while-downloading.
//!
//! The container design made every tensor an independently decodable
//! CRC'd record and every transformer layer one contiguous shard extent;
//! this module is the protocol that exploits it. The shape mirrors the
//! FLUTE/ALC sender/receiver split (block encoder / block decoder with a
//! pluggable FEC codec — RFC 6726 / RFC 5510 lineage):
//!
//! * [`fec`] — GF(2⁸) arithmetic and a systematic Reed–Solomon erasure
//!   codec behind the [`fec::FecCodec`] trait, registry-negotiated like
//!   `codec::codecs` (a [`fec::NoCode`] passthrough is id 0).
//! * [`sender`] — partitions shards into **record-aligned source
//!   blocks** (block boundaries never split a record), splits each block
//!   into `k` source symbols, and emits `k + parity` CRC-framed packets.
//! * [`transport`] — the packet channel abstraction plus a
//!   deterministic, seeded fault-injection channel (drop, burst loss,
//!   reorder, duplicate, bit-flip, truncate) for the robustness sweep.
//! * [`receiver`] — reassembles packets into blocks, FEC-repairs missing
//!   source symbols, CRC-verifies **every recovered record** via
//!   `walk_shard`, and commits files under the store's tmp+rename
//!   discipline. Nothing unverified ever becomes servable.
//! * [`availability`] — the per-stage [`availability::AvailabilityMap`]
//!   the receiver publishes as units commit; the executor's decode gate
//!   blocks on it, so layer ℓ serves bit-identically while layer ℓ+k is
//!   still in flight.
//!
//! Loss up to the parity budget is invisible: the committed store is
//! byte-identical to the source. Beyond it, everything degrades into
//! *structured* [`DistError`]s and a partial-availability report — never
//! a panic, never a silently corrupt record.

pub mod availability;
pub mod fec;
pub mod receiver;
pub mod sender;
pub mod transport;

pub use availability::{AvailabilityMap, UNIT_EMBED};
pub use fec::{fec_for, FecCodec, FecId, FecParams};
pub use receiver::{RecvReport, Receiver};
pub use sender::{Manifest, SendReport, Sender, SenderConfig, StreamPlan};
pub use transport::{
    FaultPlan, FaultyChannel, LosslessChannel, Transport, TransportStats, UdpTransport,
    UDP_MAX_PAYLOAD,
};

/// Structured distribution-path errors. The receiver's contract is that
/// every malformed packet, unrecoverable block, or corrupt record maps
/// to one of these — corruption and loss are *reported*, never panicked
/// on and never silently committed.
#[derive(Debug)]
pub enum DistError {
    /// packet does not start with the `ECP8` magic
    BadMagic,
    /// unknown packet version byte
    BadVersion(u8),
    /// packet or manifest shorter than its own framing claims
    Truncated { need: usize, have: usize },
    /// packet frame CRC mismatch (bit-flip on the wire)
    CrcMismatch { stored: u32, computed: u32 },
    /// a structurally valid packet carries impossible FEC parameters
    BadParams(&'static str),
    /// FEC encoding id not in the registry
    UnknownFec(u8),
    /// block cannot decode yet: fewer than `need` of its symbols arrived
    NeedMoreSymbols { have: usize, need: usize },
    /// packets of one block disagree about its geometry
    BlockInconsistent {
        stream: u16,
        block: u32,
        what: &'static str,
    },
    /// a fully reassembled stream failed record-level CRC verification
    RecordCorrupt { stream: u16, what: String },
    /// commit attempted while blocks are still missing
    Incomplete { missing: usize },
    Io(String),
}

impl std::fmt::Display for DistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DistError::BadMagic => write!(f, "bad magic (not an ECF8 distribution packet)"),
            DistError::BadVersion(v) => write!(f, "unsupported packet version {v}"),
            DistError::Truncated { need, have } => {
                write!(f, "packet truncated: need {need} bytes, have {have}")
            }
            DistError::CrcMismatch { stored, computed } => write!(
                f,
                "packet CRC mismatch (stored {stored:#010x}, computed {computed:#010x})"
            ),
            DistError::BadParams(what) => write!(f, "bad FEC parameters: {what}"),
            DistError::UnknownFec(id) => write!(f, "unknown FEC encoding id {id}"),
            DistError::NeedMoreSymbols { have, need } => {
                write!(f, "block undecodable: {have} of {need} required symbols")
            }
            DistError::BlockInconsistent { stream, block, what } => {
                write!(f, "stream {stream} block {block}: inconsistent packets ({what})")
            }
            DistError::RecordCorrupt { stream, what } => {
                write!(f, "stream {stream}: corrupt record after reassembly ({what})")
            }
            DistError::Incomplete { missing } => {
                write!(f, "transfer incomplete: {missing} blocks missing")
            }
            DistError::Io(what) => write!(f, "distribution i/o: {what}"),
        }
    }
}

impl std::error::Error for DistError {}

impl From<std::io::Error> for DistError {
    fn from(e: std::io::Error) -> Self {
        DistError::Io(e.to_string())
    }
}
