//! Per-stage availability: the bridge between the receiver (publishing
//! units as their bytes commit) and the executor (blocking its decode
//! gate until a stage's bytes exist on disk).
//!
//! Units use the executor's stage indexing exactly: unit 0 is the
//! embedding stage, units `1..=n_layers` are the transformer layers, and
//! unit `n_layers + 1` is the head stage (any non-layer tensor that is
//! neither embedding nor head — e.g. a final norm — rides with the head
//! unit, since the executor decodes it in that stage). The receiver maps
//! committed shards onto units via the tensor index; the executor's
//! `gate` hook calls [`AvailabilityMap::wait`] with the stage number it
//! is about to decode, so serving proceeds layer-by-layer behind the
//! download frontier and is bit-identical to a fully-local store.

use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Unit index of the embedding stage (the first executor stage).
pub const UNIT_EMBED: usize = 0;

/// A monotonic set of "these stages are servable" bits with blocking
/// waiters. Bits only ever go false→true; there is no retraction,
/// because a committed shard is never un-committed.
pub struct AvailabilityMap {
    ready: Mutex<Vec<bool>>,
    cv: Condvar,
}

impl AvailabilityMap {
    /// A map for an executor plan with `n_layers` transformer layers:
    /// `n_layers + 2` units (embed + layers + head).
    pub fn for_layers(n_layers: usize) -> Self {
        Self::new(n_layers + 2)
    }

    pub fn new(n_units: usize) -> Self {
        Self {
            ready: Mutex::new(vec![false; n_units]),
            cv: Condvar::new(),
        }
    }

    pub fn n_units(&self) -> usize {
        self.ready.lock().unwrap().len()
    }

    /// Unit index of the head stage for this map.
    pub fn unit_head(&self) -> usize {
        self.n_units() - 1
    }

    /// Mark one unit servable and wake every waiter. Idempotent.
    pub fn publish(&self, unit: usize) {
        let mut ready = self.ready.lock().unwrap();
        if unit < ready.len() && !ready[unit] {
            ready[unit] = true;
            self.cv.notify_all();
        }
    }

    /// Mark every unit servable (fully-local store, or transfer done).
    pub fn publish_all(&self) {
        let mut ready = self.ready.lock().unwrap();
        for r in ready.iter_mut() {
            *r = true;
        }
        self.cv.notify_all();
    }

    pub fn is_ready(&self, unit: usize) -> bool {
        let ready = self.ready.lock().unwrap();
        unit < ready.len() && ready[unit]
    }

    pub fn all_ready(&self) -> bool {
        self.ready.lock().unwrap().iter().all(|&r| r)
    }

    /// Servable-unit snapshot (for reports and the partial-availability
    /// printout when a transfer ends degraded).
    pub fn snapshot(&self) -> Vec<bool> {
        self.ready.lock().unwrap().clone()
    }

    /// Block until `unit` is servable. Out-of-range units (a stage plan
    /// longer than the map) are treated as ready so a mismatched plan
    /// degrades to a no-op gate instead of a deadlock.
    pub fn wait(&self, unit: usize) {
        let mut ready = self.ready.lock().unwrap();
        while unit < ready.len() && !ready[unit] {
            ready = self.cv.wait(ready).unwrap();
        }
    }

    /// Like [`wait`](Self::wait) but gives up after `timeout`; returns
    /// whether the unit became servable.
    pub fn wait_timeout(&self, unit: usize, timeout: Duration) -> bool {
        let mut ready = self.ready.lock().unwrap();
        if unit >= ready.len() {
            return true;
        }
        while !ready[unit] {
            let (guard, res) = self.cv.wait_timeout(ready, timeout).unwrap();
            ready = guard;
            if res.timed_out() {
                return ready[unit];
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn publish_is_monotonic_and_idempotent() {
        let map = AvailabilityMap::for_layers(2);
        assert_eq!(map.n_units(), 4);
        assert!(!map.is_ready(UNIT_EMBED));
        map.publish(UNIT_EMBED);
        map.publish(UNIT_EMBED);
        assert!(map.is_ready(UNIT_EMBED));
        assert!(!map.all_ready());
        map.publish_all();
        assert!(map.all_ready());
        assert_eq!(map.snapshot(), vec![true; 4]);
    }

    #[test]
    fn wait_blocks_until_published() {
        let map = Arc::new(AvailabilityMap::new(3));
        let waiter = {
            let map = Arc::clone(&map);
            std::thread::spawn(move || {
                map.wait(2);
                assert!(map.is_ready(2));
            })
        };
        // publishing a different unit must not release the waiter
        map.publish(0);
        assert!(!map.wait_timeout(2, Duration::from_millis(20)));
        map.publish(2);
        waiter.join().unwrap();
    }

    #[test]
    fn out_of_range_units_never_deadlock() {
        let map = AvailabilityMap::new(1);
        map.wait(5); // returns immediately
        assert!(map.wait_timeout(5, Duration::from_millis(1)));
        map.publish(5); // ignored, no panic
        assert!(!map.is_ready(5));
    }

    #[test]
    fn wait_timeout_reports_late_publish() {
        let map = Arc::new(AvailabilityMap::new(2));
        let publisher = {
            let map = Arc::clone(&map);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                map.publish(1);
            })
        };
        assert!(map.wait_timeout(1, Duration::from_secs(10)));
        publisher.join().unwrap();
    }
}
