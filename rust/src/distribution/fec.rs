//! Forward error correction over GF(2⁸): the [`FecCodec`] seam and its
//! two built-ins — [`NoCode`] (passthrough, id 0) and [`ReedSolomon8`]
//! (systematic Reed–Solomon erasure coding, id 1).
//!
//! The registry mirrors `codec::codecs`: senders negotiate a codec by
//! one id byte carried in every packet, receivers resolve it through
//! [`fec_for`], and an unknown id is a structured
//! [`DistError::UnknownFec`] — never a panic.
//!
//! ## The code
//!
//! A source block is `k` equal-length symbols; the encoder appends
//! `parity` repair symbols for `n = k + parity ≤ 255` total. The
//! generator matrix is the classic systematic construction: an `n × k`
//! Vandermonde matrix over GF(2⁸) (evaluation points `0..n`, all
//! distinct, so every `k × k` submatrix is invertible) multiplied by the
//! inverse of its own top square — the top `k` rows become the identity,
//! so source symbols ship unmodified and a loss-free receiver never runs
//! the decoder at all. Decoding is the dual: gather any `k` received
//! symbols, invert their generator rows (Gauss–Jordan in GF(2⁸)), and
//! reconstruct exactly the missing source symbols. Recovery succeeds
//! **iff** at least `k` of the `n` symbols arrive — the property the
//! test suite sweeps exhaustively for small geometries.

use super::DistError;
use std::sync::OnceLock;

/// GF(2⁸) modulus: x⁸ + x⁴ + x³ + x² + 1 (the AES-unrelated 0x11D used
/// by RS erasure codes; primitive element α = 2).
const GF_POLY: u32 = 0x11D;

/// Largest total symbol count (`k + parity`) one block may carry: the
/// Vandermonde evaluation points are the 255 distinct nonzero-capable
/// field indices `0..255`.
pub const MAX_TOTAL_SYMBOLS: usize = 255;

struct GfTables {
    /// α^i for i in 0..510 (doubled so `exp[log a + log b]` never wraps)
    exp: [u8; 510],
    /// log α of 1..=255 (index 0 unused)
    log: [u8; 256],
}

fn tables() -> &'static GfTables {
    static TABLES: OnceLock<GfTables> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut exp = [0u8; 510];
        let mut log = [0u8; 256];
        let mut x: u32 = 1;
        for i in 0..255 {
            exp[i] = x as u8;
            log[x as usize] = i as u8;
            x <<= 1;
            if x & 0x100 != 0 {
                x ^= GF_POLY;
            }
        }
        for i in 255..510 {
            exp[i] = exp[i - 255];
        }
        GfTables { exp, log }
    })
}

/// GF(2⁸) multiply.
#[inline]
pub fn gf_mul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        return 0;
    }
    let t = tables();
    t.exp[t.log[a as usize] as usize + t.log[b as usize] as usize]
}

/// GF(2⁸) multiplicative inverse (`a` must be nonzero).
#[inline]
pub fn gf_inv(a: u8) -> u8 {
    debug_assert_ne!(a, 0, "zero has no inverse");
    let t = tables();
    t.exp[255 - t.log[a as usize] as usize]
}

/// `x^e` in GF(2⁸) with `0^0 = 1`.
#[inline]
fn gf_pow(x: u8, e: usize) -> u8 {
    if e == 0 {
        return 1;
    }
    if x == 0 {
        return 0;
    }
    let t = tables();
    t.exp[(t.log[x as usize] as usize * e) % 255]
}

/// `dst ^= c · src`, element-wise.
fn mul_acc(dst: &mut [u8], src: &[u8], c: u8) {
    debug_assert_eq!(dst.len(), src.len());
    if c == 0 {
        return;
    }
    if c == 1 {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d ^= s;
        }
        return;
    }
    let t = tables();
    let lc = t.log[c as usize] as usize;
    for (d, &s) in dst.iter_mut().zip(src) {
        if s != 0 {
            *d ^= t.exp[lc + t.log[s as usize] as usize];
        }
    }
}

/// Gauss–Jordan inverse of a `k × k` matrix over GF(2⁸); `None` when
/// singular (cannot happen for Vandermonde-derived rows, but the decoder
/// treats it as a structured error rather than trusting that).
fn invert(mut m: Vec<Vec<u8>>) -> Option<Vec<Vec<u8>>> {
    let k = m.len();
    let mut inv: Vec<Vec<u8>> = (0..k)
        .map(|r| (0..k).map(|c| u8::from(r == c)).collect())
        .collect();
    for col in 0..k {
        let piv = (col..k).find(|&r| m[r][col] != 0)?;
        m.swap(col, piv);
        inv.swap(col, piv);
        let d = gf_inv(m[col][col]);
        for j in 0..k {
            m[col][j] = gf_mul(m[col][j], d);
            inv[col][j] = gf_mul(inv[col][j], d);
        }
        for r in 0..k {
            if r != col && m[r][col] != 0 {
                let f = m[r][col];
                for j in 0..k {
                    let a = gf_mul(f, m[col][j]);
                    let b = gf_mul(f, inv[col][j]);
                    m[r][j] ^= a;
                    inv[r][j] ^= b;
                }
            }
        }
    }
    Some(inv)
}

/// The systematic `n × k` generator matrix: Vandermonde times the
/// inverse of its top square. Rows `0..k` are the identity; any `k` rows
/// are linearly independent.
fn generator(k: usize, n: usize) -> Vec<Vec<u8>> {
    debug_assert!(k >= 1 && n >= k && n <= MAX_TOTAL_SYMBOLS);
    let vander: Vec<Vec<u8>> = (0..n)
        .map(|r| (0..k).map(|c| gf_pow(r as u8, c)).collect())
        .collect();
    let top_inv = invert(vander[..k].to_vec()).expect("Vandermonde top square is invertible");
    (0..n)
        .map(|r| {
            (0..k)
                .map(|c| {
                    let mut acc = 0u8;
                    for j in 0..k {
                        acc ^= gf_mul(vander[r][j], top_inv[j][c]);
                    }
                    acc
                })
                .collect()
        })
        .collect()
}

/// One block's negotiated FEC geometry, carried in every packet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FecParams {
    pub fec: FecId,
    /// source symbols per block
    pub k: u16,
    /// repair symbols per block
    pub parity: u16,
    /// bytes per symbol (the last source symbol is zero-padded to this)
    pub symbol_bytes: u32,
}

impl FecParams {
    /// Total symbols per block.
    pub fn n(&self) -> usize {
        self.k as usize + self.parity as usize
    }

    /// Reject impossible geometries with a structured error (packet
    /// fields are untrusted input).
    pub fn validate(&self) -> Result<(), DistError> {
        if self.k == 0 {
            return Err(DistError::BadParams("k = 0"));
        }
        if self.n() > MAX_TOTAL_SYMBOLS {
            return Err(DistError::BadParams("k + parity > 255"));
        }
        if self.symbol_bytes == 0 {
            return Err(DistError::BadParams("symbol_bytes = 0"));
        }
        if self.fec == FecId::NoCode && self.parity != 0 {
            return Err(DistError::BadParams("no-code block claims parity symbols"));
        }
        Ok(())
    }
}

/// FEC encoding id — one byte on the wire, registry index in memory
/// (mirrors `codec::CodecId`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FecId {
    /// passthrough: no repair symbols, a block decodes iff every source
    /// symbol arrives
    NoCode = 0,
    /// systematic Reed–Solomon over GF(2⁸)
    ReedSolomon8 = 1,
}

impl FecId {
    pub fn from_u8(v: u8) -> Option<Self> {
        match v {
            0 => Some(FecId::NoCode),
            1 => Some(FecId::ReedSolomon8),
            _ => None,
        }
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn label(self) -> &'static str {
        match self {
            FecId::NoCode => "no-code",
            FecId::ReedSolomon8 => "rs-gf256",
        }
    }
}

/// An erasure codec: emit repair symbols at send time, reconstruct
/// missing source symbols at receive time. Implementations are stateless
/// (`&'static` registry entries), like the container codecs.
pub trait FecCodec: Send + Sync {
    fn id(&self) -> FecId;

    /// The `params.parity` repair symbols for `source` (each slice
    /// exactly `params.symbol_bytes` long, the last one pre-padded).
    fn encode_parity(
        &self,
        params: &FecParams,
        source: &[Vec<u8>],
    ) -> Result<Vec<Vec<u8>>, DistError>;

    /// Reconstruct every missing *source* slot of `symbols` in place.
    /// `symbols` is the full `n`-slot receive window (source then
    /// parity); present slots must hold `params.symbol_bytes` bytes.
    /// Fails with [`DistError::NeedMoreSymbols`] when fewer than `k`
    /// symbols are present.
    fn recover(
        &self,
        params: &FecParams,
        symbols: &mut [Option<Vec<u8>>],
    ) -> Result<(), DistError>;
}

/// Id 0: no repair symbols; every source symbol must arrive.
pub struct NoCode;

impl FecCodec for NoCode {
    fn id(&self) -> FecId {
        FecId::NoCode
    }

    fn encode_parity(
        &self,
        params: &FecParams,
        _source: &[Vec<u8>],
    ) -> Result<Vec<Vec<u8>>, DistError> {
        params.validate()?;
        Ok(Vec::new())
    }

    fn recover(
        &self,
        params: &FecParams,
        symbols: &mut [Option<Vec<u8>>],
    ) -> Result<(), DistError> {
        params.validate()?;
        let k = params.k as usize;
        let have = symbols[..k].iter().filter(|s| s.is_some()).count();
        if have < k {
            return Err(DistError::NeedMoreSymbols { have, need: k });
        }
        Ok(())
    }
}

/// Id 1: systematic Reed–Solomon over GF(2⁸).
pub struct ReedSolomon8;

impl FecCodec for ReedSolomon8 {
    fn id(&self) -> FecId {
        FecId::ReedSolomon8
    }

    fn encode_parity(
        &self,
        params: &FecParams,
        source: &[Vec<u8>],
    ) -> Result<Vec<Vec<u8>>, DistError> {
        params.validate()?;
        let (k, sym) = (params.k as usize, params.symbol_bytes as usize);
        if source.len() != k || source.iter().any(|s| s.len() != sym) {
            return Err(DistError::BadParams("source symbol geometry"));
        }
        let g = generator(k, params.n());
        let mut parity = Vec::with_capacity(params.parity as usize);
        for row in &g[k..] {
            let mut out = vec![0u8; sym];
            for (j, src) in source.iter().enumerate() {
                mul_acc(&mut out, src, row[j]);
            }
            parity.push(out);
        }
        Ok(parity)
    }

    fn recover(
        &self,
        params: &FecParams,
        symbols: &mut [Option<Vec<u8>>],
    ) -> Result<(), DistError> {
        params.validate()?;
        let (k, n, sym) = (params.k as usize, params.n(), params.symbol_bytes as usize);
        if symbols.len() != n {
            return Err(DistError::BadParams("receive window length"));
        }
        if symbols.iter().flatten().any(|s| s.len() != sym) {
            return Err(DistError::BadParams("received symbol length"));
        }
        if symbols[..k].iter().all(|s| s.is_some()) {
            return Ok(());
        }
        let present: Vec<usize> = (0..n).filter(|&i| symbols[i].is_some()).collect();
        if present.len() < k {
            return Err(DistError::NeedMoreSymbols {
                have: present.len(),
                need: k,
            });
        }
        let g = generator(k, n);
        let rows: Vec<Vec<u8>> = present[..k].iter().map(|&i| g[i].clone()).collect();
        let inv = invert(rows).ok_or(DistError::BadParams("singular decode matrix"))?;
        let missing: Vec<usize> = (0..k).filter(|&j| symbols[j].is_none()).collect();
        for &j in &missing {
            let mut out = vec![0u8; sym];
            for (i, &idx) in present[..k].iter().enumerate() {
                let y = symbols[idx].as_ref().expect("present symbol");
                mul_acc(&mut out, y, inv[j][i]);
            }
            symbols[j] = Some(out);
        }
        Ok(())
    }
}

static NO_CODE: NoCode = NoCode;
static RS8: ReedSolomon8 = ReedSolomon8;
static REGISTRY: [&(dyn FecCodec); 2] = [&NO_CODE, &RS8];

/// Every registered FEC codec, indexed by id.
pub fn registry() -> &'static [&'static dyn FecCodec] {
    &REGISTRY
}

/// Resolve one wire id to its codec (`None` for ids not negotiated into
/// this build — the receiver maps that to [`DistError::UnknownFec`]).
pub fn fec_for(id: u8) -> Option<&'static dyn FecCodec> {
    let id = FecId::from_u8(id)?;
    registry().iter().copied().find(|c| c.id() == id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn params(k: u16, parity: u16, sym: u32) -> FecParams {
        FecParams {
            fec: FecId::ReedSolomon8,
            k,
            parity,
            symbol_bytes: sym,
        }
    }

    fn source_block(k: usize, sym: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..k)
            .map(|_| (0..sym).map(|_| rng.next_u64() as u8).collect())
            .collect()
    }

    #[test]
    fn gf_field_axioms() {
        // spot inverse + distributivity on a deterministic sweep
        for a in 1..=255u8 {
            assert_eq!(gf_mul(a, gf_inv(a)), 1, "a={a}");
            assert_eq!(gf_mul(a, 1), a);
            assert_eq!(gf_mul(a, 0), 0);
        }
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..1000 {
            let (a, b, c) = (
                rng.next_u64() as u8,
                rng.next_u64() as u8,
                rng.next_u64() as u8,
            );
            assert_eq!(gf_mul(a, b), gf_mul(b, a));
            assert_eq!(gf_mul(a, b ^ c), gf_mul(a, b) ^ gf_mul(a, c));
        }
    }

    #[test]
    fn generator_is_systematic() {
        for (k, n) in [(1usize, 3usize), (4, 6), (8, 12), (32, 40)] {
            let g = generator(k, n);
            for (r, row) in g[..k].iter().enumerate() {
                for (c, &v) in row.iter().enumerate() {
                    assert_eq!(v, u8::from(r == c), "G[{r}][{c}] of k={k}");
                }
            }
        }
    }

    #[test]
    fn parity_roundtrip_after_erasures() {
        let p = params(8, 4, 128);
        let source = source_block(8, 128, 11);
        let parity = RS8.encode_parity(&p, &source).unwrap();
        assert_eq!(parity.len(), 4);
        // drop 4 source symbols, keep all parity
        let mut window: Vec<Option<Vec<u8>>> = source.iter().cloned().map(Some).collect();
        window.extend(parity.into_iter().map(Some));
        for dead in [0usize, 2, 5, 7] {
            window[dead] = None;
        }
        RS8.recover(&p, &mut window).unwrap();
        for (j, s) in source.iter().enumerate() {
            assert_eq!(window[j].as_deref(), Some(s.as_slice()), "symbol {j}");
        }
    }

    #[test]
    fn recovers_iff_k_of_n_arrive_exhaustive() {
        // every subset of a small geometry: decode succeeds exactly when
        // ≥ k symbols survive, and always bit-exactly
        let (k, parity) = (3u16, 2u16);
        let p = params(k, parity, 16);
        let source = source_block(k as usize, 16, 21);
        let par = RS8.encode_parity(&p, &source).unwrap();
        let n = p.n();
        for mask in 0u32..(1 << n) {
            let mut window: Vec<Option<Vec<u8>>> = (0..n)
                .map(|i| {
                    if mask & (1 << i) != 0 {
                        Some(if i < k as usize {
                            source[i].clone()
                        } else {
                            par[i - k as usize].clone()
                        })
                    } else {
                        None
                    }
                })
                .collect();
            let have = mask.count_ones() as usize;
            match RS8.recover(&p, &mut window) {
                Ok(()) => {
                    assert!(have >= k as usize, "decoded from {have} < k symbols");
                    for (j, s) in source.iter().enumerate() {
                        assert_eq!(window[j].as_deref(), Some(s.as_slice()));
                    }
                }
                Err(DistError::NeedMoreSymbols { have: h, need }) => {
                    assert!(have < k as usize, "refused with {have} >= k");
                    assert_eq!(h, have);
                    assert_eq!(need, k as usize);
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
    }

    #[test]
    fn recovers_at_exactly_k_random_large() {
        // seeded random sweeps for a production-sized geometry
        let p = params(32, 8, 512);
        let source = source_block(32, 512, 33);
        let par = RS8.encode_parity(&p, &source).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(34);
        for trial in 0..40 {
            let mut window: Vec<Option<Vec<u8>>> = source.iter().cloned().map(Some).collect();
            window.extend(par.iter().cloned().map(Some));
            // erase exactly `parity` symbols (any mix) — still decodable
            let mut dead = std::collections::HashSet::new();
            while dead.len() < 8 {
                dead.insert(rng.next_below(40) as usize);
            }
            for &d in &dead {
                window[d] = None;
            }
            RS8.recover(&p, &mut window).unwrap();
            for (j, s) in source.iter().enumerate() {
                assert_eq!(window[j].as_deref(), Some(s.as_slice()), "trial {trial}");
            }
            // one more erasure than parity → structured refusal
            let mut window: Vec<Option<Vec<u8>>> = source.iter().cloned().map(Some).collect();
            window.extend(par.iter().cloned().map(Some));
            let mut dead = std::collections::HashSet::new();
            while dead.len() < 9 {
                dead.insert(rng.next_below(40) as usize);
            }
            for &d in &dead {
                window[d] = None;
            }
            match RS8.recover(&p, &mut window) {
                Err(DistError::NeedMoreSymbols { have, need }) => {
                    assert_eq!(have, 31);
                    assert_eq!(need, 32);
                }
                other => panic!("expected NeedMoreSymbols, got {other:?}"),
            }
        }
    }

    #[test]
    fn no_code_requires_every_source_symbol() {
        let p = FecParams {
            fec: FecId::NoCode,
            k: 4,
            parity: 0,
            symbol_bytes: 8,
        };
        let source = source_block(4, 8, 5);
        assert!(NO_CODE.encode_parity(&p, &source).unwrap().is_empty());
        let mut window: Vec<Option<Vec<u8>>> = source.iter().cloned().map(Some).collect();
        NO_CODE.recover(&p, &mut window).unwrap();
        window[2] = None;
        match NO_CODE.recover(&p, &mut window) {
            Err(DistError::NeedMoreSymbols { have: 3, need: 4 }) => {}
            other => panic!("expected NeedMoreSymbols, got {other:?}"),
        }
    }

    #[test]
    fn bad_params_are_structured_errors() {
        let zero_k = FecParams {
            fec: FecId::ReedSolomon8,
            k: 0,
            parity: 1,
            symbol_bytes: 8,
        };
        assert!(matches!(
            zero_k.validate(),
            Err(DistError::BadParams("k = 0"))
        ));
        let too_many = params(200, 100, 8);
        assert!(matches!(too_many.validate(), Err(DistError::BadParams(_))));
        let fake_parity = FecParams {
            fec: FecId::NoCode,
            k: 4,
            parity: 2,
            symbol_bytes: 8,
        };
        assert!(matches!(
            fake_parity.validate(),
            Err(DistError::BadParams(_))
        ));
    }

    #[test]
    fn registry_resolves_ids() {
        assert_eq!(fec_for(0).unwrap().id(), FecId::NoCode);
        assert_eq!(fec_for(1).unwrap().id(), FecId::ReedSolomon8);
        assert!(fec_for(7).is_none());
        assert_eq!(FecId::ReedSolomon8.label(), "rs-gf256");
    }
}
