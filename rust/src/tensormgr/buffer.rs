//! The §3.3 shared decode buffer: "a single pre-allocated GPU memory
//! buffer of size equal to the largest layer's weight tensor, eliminating
//! dynamic memory allocation overhead during inference".
//!
//! Here the buffer is host memory handed to PJRT; the contract is the
//! same — zero allocation on the request path, reused across layers.

/// A reusable, pre-allocated decode target.
pub struct DecodeBuffer {
    buf: Vec<u8>,
    /// high-water mark of requested sizes (for diagnostics)
    peak_request: usize,
}

impl DecodeBuffer {
    /// Allocate once with the largest tensor size the model needs.
    pub fn with_capacity(bytes: usize) -> Self {
        Self {
            buf: vec![0u8; bytes],
            peak_request: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    pub fn peak_request(&self) -> usize {
        self.peak_request
    }

    /// Borrow the first `n` bytes. Panics if the buffer was sized too
    /// small — that's a configuration bug (the §3.3 invariant is that the
    /// buffer covers the largest layer).
    pub fn slice_mut(&mut self, n: usize) -> &mut [u8] {
        assert!(
            n <= self.buf.len(),
            "decode buffer too small: need {n}, have {}",
            self.buf.len()
        );
        self.peak_request = self.peak_request.max(n);
        &mut self.buf[..n]
    }

    pub fn slice(&self, n: usize) -> &[u8] {
        &self.buf[..n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_without_reallocation() {
        let mut b = DecodeBuffer::with_capacity(1024);
        let p0 = b.slice_mut(512).as_ptr() as usize;
        let p1 = b.slice_mut(1024).as_ptr() as usize;
        assert_eq!(p0, p1, "no reallocation");
        assert_eq!(b.peak_request(), 1024);
    }

    #[test]
    #[should_panic(expected = "decode buffer too small")]
    fn oversized_request_panics() {
        let mut b = DecodeBuffer::with_capacity(8);
        b.slice_mut(9);
    }
}
