//! The §3.3 shared decode buffer: "a single pre-allocated GPU memory
//! buffer of size equal to the largest layer's weight tensor, eliminating
//! dynamic memory allocation overhead during inference".
//!
//! Here the buffer is host memory handed to PJRT; the contract is the
//! same — zero allocation on the request path, reused across layers.
//!
//! Two usage modes:
//!
//! * **whole-buffer** ([`DecodeBuffer::slice_mut`]) — one tensor at a
//!   time, the original §3.3 shape;
//! * **arena** ([`DecodeBuffer::reset`] / [`DecodeBuffer::alloc_mut`]) —
//!   bump-allocate every tensor of a layer so the zero-copy serving path
//!   can hand PJRT borrowed slices of all of them simultaneously,
//!   without the per-tensor `to_vec` copies the pre-arena executor made.
//!   Sized to the largest layer up front, the arena never reallocates on
//!   the request path; undersized buffers grow once per high-water mark
//!   during warm-up.

/// A reusable, pre-allocated decode target.
pub struct DecodeBuffer {
    buf: Vec<u8>,
    /// arena bump pointer (whole-buffer mode ignores it)
    used: usize,
    /// high-water mark of requested sizes (for diagnostics)
    peak_request: usize,
}

impl DecodeBuffer {
    /// Allocate once with the largest layer working-set the model needs.
    pub fn with_capacity(bytes: usize) -> Self {
        Self {
            buf: vec![0u8; bytes],
            used: 0,
            peak_request: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    pub fn peak_request(&self) -> usize {
        self.peak_request
    }

    /// Borrow the first `n` bytes (whole-buffer mode). Panics if the
    /// buffer was sized too small — that's a configuration bug (the §3.3
    /// invariant is that the buffer covers the largest layer).
    pub fn slice_mut(&mut self, n: usize) -> &mut [u8] {
        assert!(
            n <= self.buf.len(),
            "decode buffer too small: need {n}, have {}",
            self.buf.len()
        );
        self.peak_request = self.peak_request.max(n);
        &mut self.buf[..n]
    }

    pub fn slice(&self, n: usize) -> &[u8] {
        &self.buf[..n]
    }

    /// Recycle the arena (start of a new layer). O(1): no zeroing, the
    /// decoder overwrites every allocated byte.
    pub fn reset(&mut self) {
        self.used = 0;
    }

    /// Bump-allocate `n` bytes and return (range, mutable slice). Grows
    /// the backing store when the high-water mark rises (warm-up only in
    /// a correctly-sized deployment); previously returned ranges stay
    /// valid because they are offsets, not pointers.
    pub fn alloc_mut(&mut self, n: usize) -> (std::ops::Range<usize>, &mut [u8]) {
        let start = self.used;
        let end = start + n;
        if end > self.buf.len() {
            self.buf.resize(end, 0);
        }
        self.used = end;
        self.peak_request = self.peak_request.max(end);
        (start..end, &mut self.buf[start..end])
    }

    /// Bytes currently allocated in arena mode.
    pub fn used(&self) -> usize {
        self.used
    }

    /// The whole backing store (index with ranges from
    /// [`DecodeBuffer::alloc_mut`]).
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_without_reallocation() {
        let mut b = DecodeBuffer::with_capacity(1024);
        let p0 = b.slice_mut(512).as_ptr() as usize;
        let p1 = b.slice_mut(1024).as_ptr() as usize;
        assert_eq!(p0, p1, "no reallocation");
        assert_eq!(b.peak_request(), 1024);
    }

    #[test]
    #[should_panic(expected = "decode buffer too small")]
    fn oversized_request_panics() {
        let mut b = DecodeBuffer::with_capacity(8);
        b.slice_mut(9);
    }

    #[test]
    fn arena_allocations_are_disjoint_and_stable() {
        let mut b = DecodeBuffer::with_capacity(64);
        let (r1, s1) = b.alloc_mut(10);
        s1.fill(0xAA);
        let (r2, s2) = b.alloc_mut(20);
        s2.fill(0xBB);
        assert_eq!(r1, 0..10);
        assert_eq!(r2, 10..30);
        assert_eq!(b.used(), 30);
        assert!(b.bytes()[r1].iter().all(|&x| x == 0xAA));
        assert!(b.bytes()[r2].iter().all(|&x| x == 0xBB));
        let base = b.bytes().as_ptr() as usize;
        b.reset();
        assert_eq!(b.used(), 0);
        let (_, s) = b.alloc_mut(64);
        assert_eq!(s.as_ptr() as usize, base, "steady state never reallocates");
    }

    #[test]
    fn arena_grows_past_capacity_during_warmup() {
        let mut b = DecodeBuffer::with_capacity(4);
        let (r, s) = b.alloc_mut(16);
        s.fill(1);
        assert_eq!(r, 0..16);
        assert_eq!(b.peak_request(), 16);
        assert!(b.capacity() >= 16);
    }
}
