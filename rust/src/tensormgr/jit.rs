//! Just-in-time layer decompression (§3.3): the forward hook analogue.
//!
//! Before layer ℓᵢ executes, its tensors are decoded from their
//! [`CompressedTensor`] records (the codec seam — ECF8 blobs, raw-FP8
//! passthrough, or any registered codec) into the shared
//! [`DecodeBuffer`]; the buffer is recycled for
//! ℓᵢ₊₁ as soon as ℓᵢ's execution has consumed it (PJRT copies inputs
//! into device buffers at execute time, matching the paper's
//! "buffer becomes available after the layer's forward pass").
//!
//! Two access patterns, cheapest last:
//!
//! * [`JitDecompressor::with_decoded`] — decode one tensor, borrow it
//!   inside a closure (the original API; callers that need the bytes
//!   past the closure still copy);
//! * arena mode ([`JitDecompressor::begin_layer`] /
//!   [`JitDecompressor::decode_to_arena`] /
//!   [`JitDecompressor::arena`]) — decode a whole layer into the shared
//!   buffer and hand out `Range` handles, so every weight of the layer
//!   can be *borrowed* simultaneously with zero copies.
//!
//! Decode-*ahead* (layer ℓ+1 decoding while layer ℓ executes) is no
//! longer implemented here: it moved to the serving coordinator's decode
//! stage ([`crate::coordinator::decode_stage`]), which pulls per-tensor
//! decode work off the shared thread pool and recycles the
//! [`LayerArena`]s this module still owns (via
//! [`JitDecompressor::decode_ahead_parts`]).
//!
//! All paths share one [`DecodeTableCache`] keyed by code book, so the
//! multi-symbol LUT tiers are built once per distinct book (layers often
//! share books) instead of once per decode call. Tensors on codecs
//! without a code book (raw passthrough) simply carry no table entry.

use super::buffer::DecodeBuffer;
use crate::codec::decode::{DecodeTableCache, DecodeTables};
use crate::codec::CompressedTensor;
use crate::util::threadpool::ThreadPool;
use std::ops::Range;
use std::sync::Arc;

/// Decompression statistics (per model forward).
#[derive(Debug, Default, Clone, Copy)]
pub struct JitStats {
    pub tensors_decoded: u64,
    pub bytes_decoded: u64,
    /// foreground decode wall time; decode-ahead time is hidden behind
    /// compute and intentionally not accumulated here
    pub decode_seconds: f64,
}

/// Decode `tensors[i]` into `extents[i]` of `buf`, one work item per
/// tensor on `pool` (serial without one). The one disjoint-extent
/// parallel-fill primitive, shared by the decode-ahead
/// [`LayerArena`]s (prefix-sum extents into a stage arena) and the
/// KV-cache restore path (`scheduler::kv_cache`, arbitrary block
/// extents into the block slab) — both have the same shape: many
/// independent codec decodes writing non-overlapping windows of one
/// buffer.
///
/// The extents must be pairwise disjoint and in-bounds; this is
/// checked up front (it is the safety contract of the raw-pointer
/// writes the workers do).
pub fn decode_into_disjoint(
    buf: &mut [u8],
    extents: &[Range<usize>],
    tensors: &[&CompressedTensor],
    tables: &[Option<Arc<DecodeTables>>],
    pool: Option<&ThreadPool>,
) {
    assert_eq!(extents.len(), tensors.len(), "one extent per tensor");
    assert_eq!(tensors.len(), tables.len(), "one table slot per tensor");
    // Well-formedness + bounds for EVERY extent, then disjointness over
    // a sorted copy. Cheap (extent counts are per-stage / per-sequence,
    // not per-element) and it is what makes the unsafe below sound
    // against any caller — an inverted range must never reach the
    // raw-pointer slice construction.
    for r in extents {
        assert!(r.start <= r.end && r.end <= buf.len(), "extent out of bounds");
    }
    let mut sorted: Vec<&Range<usize>> = extents.iter().collect();
    sorted.sort_by_key(|r| (r.start, r.end));
    for w in sorted.windows(2) {
        assert!(w[0].end <= w[1].start, "overlapping extents");
    }
    // SAFETY-SUPPORT: hand workers the base address; the extents were
    // just proven disjoint and in-bounds (same contract as the
    // block-parallel decoder).
    let base_addr = buf.as_mut_ptr() as usize;
    let decode_one = |i: usize| {
        let r = &extents[i];
        // SAFETY: extents are disjoint across i and within the buffer;
        // no other code touches the buffer while this runs.
        let dst = unsafe {
            std::slice::from_raw_parts_mut((base_addr as *mut u8).add(r.start), r.end - r.start)
        };
        tensors[i].decode_into_cached(dst, None, tables[i].as_deref());
    };
    match pool {
        Some(pool) if tensors.len() > 1 => {
            pool.scope_chunks(tensors.len(), tensors.len(), |_, s, e| {
                for i in s..e {
                    decode_one(i);
                }
            });
        }
        _ => {
            for i in 0..tensors.len() {
                decode_one(i);
            }
        }
    }
}

/// One decoded pipeline stage (a layer's worth of tensors): a private
/// arena plus per-tensor extents, in blob order. Filled by the
/// coordinator's decode stage, borrowed by the executor.
#[derive(Default)]
pub struct LayerArena {
    buf: Vec<u8>,
    ends: Vec<usize>,
}

impl LayerArena {
    /// Lay out the arena for `tensors`: per-tensor extents computed,
    /// backing store grown if needed (steady state: no allocation —
    /// arenas are recycled across forwards at the model's high-water
    /// mark).
    pub fn prepare(&mut self, tensors: &[&CompressedTensor]) {
        self.ends.clear();
        let mut off = 0usize;
        for tensor in tensors {
            off += tensor.n_elem();
            self.ends.push(off);
        }
        if self.buf.len() < off {
            self.buf.resize(off, 0);
        }
    }

    /// Decode every tensor of the stage into its extent. With a pool,
    /// each tensor is an independent work item (the coordinator pipeline's
    /// per-tensor decode granularity); tensors write disjoint extents, so
    /// they parallelise without coordination. Serial without a pool.
    pub fn decode_stage_tensors(
        &mut self,
        tensors: &[&CompressedTensor],
        tables: &[Option<Arc<DecodeTables>>],
        pool: Option<&ThreadPool>,
    ) {
        self.prepare(tensors);
        let extents: Vec<Range<usize>> = self
            .ends
            .iter()
            .enumerate()
            .map(|(i, &end)| if i == 0 { 0..end } else { self.ends[i - 1]..end })
            .collect();
        decode_into_disjoint(&mut self.buf, &extents, tensors, tables, pool);
    }

    /// Decoded bytes of the `i`-th tensor of this stage.
    pub fn tensor(&self, i: usize) -> &[u8] {
        let start = if i == 0 { 0 } else { self.ends[i - 1] };
        &self.buf[start..self.ends[i]]
    }

    /// Number of tensors decoded into this arena.
    pub fn len(&self) -> usize {
        self.ends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ends.is_empty()
    }
}

/// JIT decompressor bound to a shared decode buffer.
pub struct JitDecompressor {
    buffer: DecodeBuffer,
    pool: Option<Arc<ThreadPool>>,
    stats: JitStats,
    /// decode tiers per canonical code book (keyed by stored lengths)
    tables: DecodeTableCache,
    /// recycled decode-ahead arenas, so steady-state pipelined forwards
    /// allocate nothing (filled/drained by the coordinator decode stage)
    spare_arenas: Vec<LayerArena>,
}

impl JitDecompressor {
    /// `buffer_bytes` — the largest layer working-set in the model (the
    /// §3.3 buffer size); `pool` — optional block-parallel decode.
    pub fn new(buffer_bytes: usize, pool: Option<Arc<ThreadPool>>) -> Self {
        Self {
            buffer: DecodeBuffer::with_capacity(buffer_bytes),
            pool,
            stats: JitStats::default(),
            tables: DecodeTableCache::new(),
            spare_arenas: Vec::new(),
        }
    }

    /// Cached decode tiers for `tensor`'s code book (built on first
    /// use); `None` when its codec needs no tables (raw passthrough).
    pub fn tables_for(&mut self, tensor: &CompressedTensor) -> Option<Arc<DecodeTables>> {
        tensor.tables(&mut self.tables)
    }

    /// The pieces the coordinator's decode-ahead stage needs: the shared
    /// table cache and the recycled arena pool. Split-borrowed so callers
    /// can hold blob borrows of the model at the same time.
    pub fn decode_ahead_parts(&mut self) -> (&mut DecodeTableCache, &mut Vec<LayerArena>) {
        (&mut self.tables, &mut self.spare_arenas)
    }

    /// Account decode-ahead work performed on this decompressor's behalf
    /// (the decode stage hides its wall time behind compute, so only
    /// volume counters move).
    pub fn record_decoded(&mut self, tensors: u64, bytes: u64) {
        self.stats.tensors_decoded += tensors;
        self.stats.bytes_decoded += bytes;
    }

    /// Decode `tensor` into the shared buffer and run `consume` on the
    /// decoded bytes (the layer execution). The buffer is free again when
    /// this returns.
    pub fn with_decoded<R>(
        &mut self,
        tensor: &CompressedTensor,
        consume: impl FnOnce(&[u8]) -> R,
    ) -> R {
        let t0 = std::time::Instant::now();
        let tables = tensor.tables(&mut self.tables);
        let pool = self.pool.clone();
        let n = tensor.n_elem();
        let dst = self.buffer.slice_mut(n);
        tensor.decode_into_cached(dst, pool.as_deref(), tables.as_deref());
        self.stats.tensors_decoded += 1;
        self.stats.bytes_decoded += n as u64;
        self.stats.decode_seconds += t0.elapsed().as_secs_f64();
        consume(self.buffer.slice(n))
    }

    /// Decode a set of tensors sequentially into the shared buffer,
    /// calling `consume` once per tensor (layer-by-layer order).
    pub fn for_each_decoded(
        &mut self,
        tensors: &[&CompressedTensor],
        mut consume: impl FnMut(usize, &[u8]),
    ) {
        for (i, tensor) in tensors.iter().enumerate() {
            self.with_decoded(tensor, |bytes| consume(i, bytes));
        }
    }

    /// Start a new layer in arena mode: recycles the shared buffer.
    pub fn begin_layer(&mut self) {
        self.buffer.reset();
    }

    /// Decode `tensor` into the arena and return its extent. Slices of
    /// all tensors decoded since [`Self::begin_layer`] stay simultaneously
    /// valid — index [`Self::arena`] with the returned ranges.
    pub fn decode_to_arena(&mut self, tensor: &CompressedTensor) -> Range<usize> {
        let t0 = std::time::Instant::now();
        let tables = tensor.tables(&mut self.tables);
        let pool = self.pool.clone();
        let n = tensor.n_elem();
        let (range, dst) = self.buffer.alloc_mut(n);
        tensor.decode_into_cached(dst, pool.as_deref(), tables.as_deref());
        self.stats.tensors_decoded += 1;
        self.stats.bytes_decoded += n as u64;
        self.stats.decode_seconds += t0.elapsed().as_secs_f64();
        range
    }

    /// The arena backing store (borrow with ranges from
    /// [`Self::decode_to_arena`]).
    pub fn arena(&self) -> &[u8] {
        self.buffer.bytes()
    }

    pub fn stats(&self) -> JitStats {
        self.stats
    }

    pub fn buffer_capacity(&self) -> usize {
        self.buffer.capacity()
    }

    /// Decode throughput so far (bytes of FP8 produced per second).
    pub fn decode_throughput_bps(&self) -> f64 {
        if self.stats.decode_seconds == 0.0 {
            return 0.0;
        }
        self.stats.bytes_decoded as f64 / self.stats.decode_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::codecs::RawTensor;
    use crate::codec::{compress_fp8, Fp8Format};
    use crate::util::prng::Xoshiro256;

    fn blob(n: usize, seed: u64) -> (Vec<u8>, CompressedTensor) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let data: Vec<u8> = (0..n)
            .map(|_| {
                let x = (crate::util::sampling::normal(&mut rng) * 0.05) as f32;
                crate::fp8::F8E4M3::from_f32(x).to_bits()
            })
            .collect();
        let b = CompressedTensor::Ecf8(compress_fp8(&data));
        (data, b)
    }

    fn raw(n: usize, seed: u64) -> (Vec<u8>, CompressedTensor) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let data: Vec<u8> = (0..n).map(|_| (rng.next_u64() >> 56) as u8).collect();
        let t = CompressedTensor::Raw(RawTensor {
            format: Fp8Format::E4M3,
            bytes: data.clone().into(),
        });
        (data, t)
    }

    #[test]
    fn decodes_bit_exact_into_shared_buffer() {
        let (d1, b1) = blob(10_000, 1);
        let (d2, b2) = blob(5_000, 2);
        let mut jit = JitDecompressor::new(10_000, None);
        jit.with_decoded(&b1, |bytes| assert_eq!(bytes, &d1[..]));
        jit.with_decoded(&b2, |bytes| assert_eq!(bytes, &d2[..]));
        assert_eq!(jit.stats().tensors_decoded, 2);
        assert_eq!(jit.stats().bytes_decoded, 15_000);
    }

    #[test]
    fn parallel_pool_gives_same_bytes() {
        let pool = Arc::new(ThreadPool::new(4));
        let (d, b) = blob(300_000, 3);
        let mut jit = JitDecompressor::new(300_000, Some(pool));
        jit.with_decoded(&b, |bytes| assert_eq!(bytes, &d[..]));
    }

    #[test]
    fn for_each_decoded_visits_in_order() {
        let (d1, b1) = blob(1000, 4);
        let (d2, b2) = blob(2000, 5);
        let mut jit = JitDecompressor::new(2000, None);
        let mut seen = Vec::new();
        jit.for_each_decoded(&[&b1, &b2], |i, bytes| {
            seen.push((i, bytes.len()));
            if i == 0 {
                assert_eq!(bytes, &d1[..]);
            } else {
                assert_eq!(bytes, &d2[..]);
            }
        });
        assert_eq!(seen, vec![(0, 1000), (1, 2000)]);
    }

    #[test]
    fn throughput_reported() {
        let (_, b) = blob(100_000, 6);
        let mut jit = JitDecompressor::new(100_000, None);
        jit.with_decoded(&b, |_| ());
        assert!(jit.decode_throughput_bps() > 0.0);
    }

    #[test]
    fn arena_holds_a_whole_layer_zero_copy() {
        let (d1, b1) = blob(10_000, 7);
        let (d2, b2) = blob(4_000, 8);
        let (d3, b3) = blob(6_000, 9);
        let mut jit = JitDecompressor::new(20_000, None);
        jit.begin_layer();
        let r1 = jit.decode_to_arena(&b1);
        let r2 = jit.decode_to_arena(&b2);
        let r3 = jit.decode_to_arena(&b3);
        // all three live at once, borrowed straight from the buffer
        let arena = jit.arena();
        assert_eq!(&arena[r1], &d1[..]);
        assert_eq!(&arena[r2], &d2[..]);
        assert_eq!(&arena[r3], &d3[..]);
        // recycling reuses the same memory
        jit.begin_layer();
        let r1b = jit.decode_to_arena(&b1);
        assert_eq!(r1b, 0..10_000);
        assert_eq!(&jit.arena()[r1b], &d1[..]);
    }

    #[test]
    fn layer_arena_decodes_tensors_bit_exact_serial_and_parallel() {
        let (d1, b1) = blob(8_000, 10);
        let (d2, b2) = blob(3_000, 11);
        let (d3, b3) = blob(5_000, 12);
        let blobs: Vec<&CompressedTensor> = vec![&b1, &b2, &b3];
        let mut cache = DecodeTableCache::new();
        let tables: Vec<Option<Arc<DecodeTables>>> =
            blobs.iter().map(|b| b.tables(&mut cache)).collect();

        let mut arena = LayerArena::default();
        arena.decode_stage_tensors(&blobs, &tables, None);
        assert_eq!(arena.len(), 3);
        assert_eq!(arena.tensor(0), &d1[..]);
        assert_eq!(arena.tensor(1), &d2[..]);
        assert_eq!(arena.tensor(2), &d3[..]);

        let pool = ThreadPool::new(3);
        let mut par = LayerArena::default();
        par.decode_stage_tensors(&blobs, &tables, Some(&pool));
        assert_eq!(par.tensor(0), &d1[..]);
        assert_eq!(par.tensor(1), &d2[..]);
        assert_eq!(par.tensor(2), &d3[..]);

        // recycling with a different stage shape stays exact
        par.decode_stage_tensors(&[&b2], &tables[1..2], Some(&pool));
        assert_eq!(par.len(), 1);
        assert_eq!(par.tensor(0), &d2[..]);
    }

    #[test]
    fn decode_into_disjoint_handles_non_monotone_extents() {
        // the KV-restore shape: block extents in table order, not in
        // ascending buffer order, with a partially filled last block
        let (d1, b1) = blob(1_024, 30);
        let (d2, b2) = blob(1_024, 31);
        let (d3, b3) = blob(512, 32);
        let mut cache = DecodeTableCache::new();
        let tensors: Vec<&CompressedTensor> = vec![&b1, &b2, &b3];
        let tables: Vec<Option<Arc<DecodeTables>>> =
            tensors.iter().map(|t| t.tables(&mut cache)).collect();
        let mut slab = vec![0u8; 4 * 1_024];
        // tensor 0 → block 2, tensor 1 → block 0, tensor 2 → half of block 3
        let extents = vec![2_048..3_072, 0..1_024, 3_072..3_584];
        decode_into_disjoint(&mut slab, &extents, &tensors, &tables, None);
        assert_eq!(&slab[2_048..3_072], &d1[..]);
        assert_eq!(&slab[0..1_024], &d2[..]);
        assert_eq!(&slab[3_072..3_584], &d3[..]);
        assert!(slab[1_024..2_048].iter().all(|&b| b == 0), "untouched block");
        // parallel fill is bit-identical
        let pool = ThreadPool::new(2);
        let mut par = vec![0u8; 4 * 1_024];
        decode_into_disjoint(&mut par, &extents, &tensors, &tables, Some(&pool));
        assert_eq!(par, slab);
    }

    #[test]
    #[should_panic(expected = "overlapping extents")]
    fn decode_into_disjoint_rejects_overlap() {
        let (_, b1) = blob(100, 33);
        let (_, b2) = blob(100, 34);
        let mut cache = DecodeTableCache::new();
        let tensors: Vec<&CompressedTensor> = vec![&b1, &b2];
        let tables: Vec<Option<Arc<DecodeTables>>> =
            tensors.iter().map(|t| t.tables(&mut cache)).collect();
        let mut buf = vec![0u8; 256];
        decode_into_disjoint(&mut buf, &[0..100, 50..150], &tensors, &tables, None);
    }

    #[test]
    #[should_panic(expected = "extent out of bounds")]
    fn decode_into_disjoint_rejects_inverted_range() {
        // an inverted non-last range must be caught by the up-front
        // validation, never reach the raw-pointer slice construction
        let (_, b1) = blob(100, 35);
        let (_, b2) = blob(40, 36);
        let mut cache = DecodeTableCache::new();
        let tensors: Vec<&CompressedTensor> = vec![&b1, &b2];
        let tables: Vec<Option<Arc<DecodeTables>>> =
            tensors.iter().map(|t| t.tables(&mut cache)).collect();
        let mut buf = vec![0u8; 256];
        #[allow(clippy::reversed_empty_ranges)]
        let extents = [150..50, 200..240];
        decode_into_disjoint(&mut buf, &extents, &tensors, &tables, None);
    }

    #[test]
    fn mixed_codec_stage_decodes_bit_exact() {
        // an ECF8 tensor and a raw-passthrough tensor share one arena
        let (d1, b1) = blob(6_000, 20);
        let (d2, b2) = raw(2_500, 21);
        let tensors: Vec<&CompressedTensor> = vec![&b1, &b2];
        let mut cache = DecodeTableCache::new();
        let tables: Vec<Option<Arc<DecodeTables>>> =
            tensors.iter().map(|t| t.tables(&mut cache)).collect();
        assert!(tables[0].is_some());
        assert!(tables[1].is_none(), "raw passthrough needs no tables");
        let mut arena = LayerArena::default();
        arena.decode_stage_tensors(&tensors, &tables, None);
        assert_eq!(arena.tensor(0), &d1[..]);
        assert_eq!(arena.tensor(1), &d2[..]);
        // and through the jit buffer paths
        let mut jit = JitDecompressor::new(6_000, None);
        jit.with_decoded(&b2, |bytes| assert_eq!(bytes, &d2[..]));
        jit.begin_layer();
        let r = jit.decode_to_arena(&b2);
        assert_eq!(&jit.arena()[r], &d2[..]);
    }

    #[test]
    fn decode_ahead_parts_share_table_cache() {
        let (_, b1) = blob(2_000, 14);
        let mut jit = JitDecompressor::new(0, None);
        let t1 = jit.tables_for(&b1).expect("ecf8 tensor has tables");
        let (cache, spares) = jit.decode_ahead_parts();
        let t2 = b1.tables(cache).expect("ecf8 tensor has tables");
        assert!(Arc::ptr_eq(&t1, &t2), "same cached tables");
        assert!(spares.is_empty());
        spares.push(LayerArena::default());
        jit.record_decoded(1, 2_000);
        assert_eq!(jit.stats().tensors_decoded, 1);
        assert_eq!(jit.stats().bytes_decoded, 2_000);
    }
}
