//! Just-in-time layer decompression (§3.3): the forward hook analogue.
//!
//! Before layer ℓᵢ executes, its tensors are decoded from their ECF8
//! blobs into the shared [`DecodeBuffer`]; the buffer is recycled for
//! ℓᵢ₊₁ as soon as ℓᵢ's execution has consumed it (PJRT copies inputs
//! into device buffers at execute time, matching the paper's
//! "buffer becomes available after the layer's forward pass").
//!
//! Optional prefetch: with a thread pool, the next layer's tensors are
//! decoded into a second buffer while the current layer executes —
//! double buffering, the standard latency-hiding move.

use super::buffer::DecodeBuffer;
use crate::codec::decode::decode_into;
use crate::codec::Ecf8Blob;
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Decompression statistics (per model forward).
#[derive(Debug, Default, Clone, Copy)]
pub struct JitStats {
    pub tensors_decoded: u64,
    pub bytes_decoded: u64,
    pub decode_seconds: f64,
}

/// JIT decompressor bound to a shared decode buffer.
pub struct JitDecompressor {
    buffer: DecodeBuffer,
    pool: Option<Arc<ThreadPool>>,
    stats: JitStats,
}

impl JitDecompressor {
    /// `max_tensor_bytes` — the largest decoded tensor in the model
    /// (the §3.3 buffer size); `pool` — optional block-parallel decode.
    pub fn new(max_tensor_bytes: usize, pool: Option<Arc<ThreadPool>>) -> Self {
        Self {
            buffer: DecodeBuffer::with_capacity(max_tensor_bytes),
            pool,
            stats: JitStats::default(),
        }
    }

    /// Decode `blob` into the shared buffer and run `consume` on the
    /// decoded bytes (the layer execution). The buffer is free again when
    /// this returns.
    pub fn with_decoded<R>(&mut self, blob: &Ecf8Blob, consume: impl FnOnce(&[u8]) -> R) -> R {
        let t0 = std::time::Instant::now();
        let dst = self.buffer.slice_mut(blob.n_elem);
        decode_into(blob, dst, self.pool.as_deref());
        self.stats.tensors_decoded += 1;
        self.stats.bytes_decoded += blob.n_elem as u64;
        self.stats.decode_seconds += t0.elapsed().as_secs_f64();
        consume(self.buffer.slice(blob.n_elem))
    }

    /// Decode a set of tensors sequentially into the shared buffer,
    /// calling `consume` once per tensor (layer-by-layer order).
    pub fn for_each_decoded(
        &mut self,
        blobs: &[&Ecf8Blob],
        mut consume: impl FnMut(usize, &[u8]),
    ) {
        for (i, blob) in blobs.iter().enumerate() {
            self.with_decoded(blob, |bytes| consume(i, bytes));
        }
    }

    pub fn stats(&self) -> JitStats {
        self.stats
    }

    pub fn buffer_capacity(&self) -> usize {
        self.buffer.capacity()
    }

    /// Decode throughput so far (bytes of FP8 produced per second).
    pub fn decode_throughput_bps(&self) -> f64 {
        if self.stats.decode_seconds == 0.0 {
            return 0.0;
        }
        self.stats.bytes_decoded as f64 / self.stats.decode_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::compress_fp8;
    use crate::util::prng::Xoshiro256;

    fn blob(n: usize, seed: u64) -> (Vec<u8>, Ecf8Blob) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let data: Vec<u8> = (0..n)
            .map(|_| {
                let x = (crate::util::sampling::normal(&mut rng) * 0.05) as f32;
                crate::fp8::F8E4M3::from_f32(x).to_bits()
            })
            .collect();
        let b = compress_fp8(&data);
        (data, b)
    }

    #[test]
    fn decodes_bit_exact_into_shared_buffer() {
        let (d1, b1) = blob(10_000, 1);
        let (d2, b2) = blob(5_000, 2);
        let mut jit = JitDecompressor::new(10_000, None);
        jit.with_decoded(&b1, |bytes| assert_eq!(bytes, &d1[..]));
        jit.with_decoded(&b2, |bytes| assert_eq!(bytes, &d2[..]));
        assert_eq!(jit.stats().tensors_decoded, 2);
        assert_eq!(jit.stats().bytes_decoded, 15_000);
    }

    #[test]
    fn parallel_pool_gives_same_bytes() {
        let pool = Arc::new(ThreadPool::new(4));
        let (d, b) = blob(300_000, 3);
        let mut jit = JitDecompressor::new(300_000, Some(pool));
        jit.with_decoded(&b, |bytes| assert_eq!(bytes, &d[..]));
    }

    #[test]
    fn for_each_decoded_visits_in_order() {
        let (d1, b1) = blob(1000, 4);
        let (d2, b2) = blob(2000, 5);
        let mut jit = JitDecompressor::new(2000, None);
        let mut seen = Vec::new();
        jit.for_each_decoded(&[&b1, &b2], |i, bytes| {
            seen.push((i, bytes.len()));
            if i == 0 {
                assert_eq!(bytes, &d1[..]);
            } else {
                assert_eq!(bytes, &d2[..]);
            }
        });
        assert_eq!(seen, vec![(0, 1000), (1, 2000)]);
    }

    #[test]
    fn throughput_reported() {
        let (_, b) = blob(100_000, 6);
        let mut jit = JitDecompressor::new(100_000, None);
        jit.with_decoded(&b, |_| ());
        assert!(jit.decode_throughput_bps() > 0.0);
    }
}
