//! Just-in-time layer decompression (§3.3): the forward hook analogue.
//!
//! Before layer ℓᵢ executes, its tensors are decoded from their ECF8
//! blobs into the shared [`DecodeBuffer`]; the buffer is recycled for
//! ℓᵢ₊₁ as soon as ℓᵢ's execution has consumed it (PJRT copies inputs
//! into device buffers at execute time, matching the paper's
//! "buffer becomes available after the layer's forward pass").
//!
//! Three access patterns, cheapest last:
//!
//! * [`JitDecompressor::with_decoded`] — decode one tensor, borrow it
//!   inside a closure (the original API; callers that need the bytes
//!   past the closure still copy);
//! * arena mode ([`JitDecompressor::begin_layer`] /
//!   [`JitDecompressor::decode_to_arena`] /
//!   [`JitDecompressor::arena`]) — decode a whole layer into the shared
//!   buffer and hand out `Range` handles, so every weight of the layer
//!   can be *borrowed* simultaneously with zero copies;
//! * decode-ahead ([`JitDecompressor::with_layers_decoded`]) — a
//!   background thread decodes layer ℓ+1 into a second arena while the
//!   caller's closure executes layer ℓ (double buffering, the standard
//!   latency-hiding move). The ahead-decoder runs serially on its own
//!   thread — block-parallel decode there would contend with the
//!   executing layer's compute.
//!
//! All paths share one [`DecodeTables`] cache keyed by code book, so the
//! multi-symbol LUT tiers are built once per distinct book (layers often
//! share books) instead of once per decode call.

use super::buffer::DecodeBuffer;
use crate::codec::decode::{decode_into_cached, DecodeTables};
use crate::codec::Ecf8Blob;
use crate::util::threadpool::ThreadPool;
use std::collections::HashMap;
use std::ops::Range;
use std::sync::{mpsc, Arc};

/// Decompression statistics (per model forward).
#[derive(Debug, Default, Clone, Copy)]
pub struct JitStats {
    pub tensors_decoded: u64,
    pub bytes_decoded: u64,
    /// foreground decode wall time; decode-ahead time is hidden behind
    /// compute and intentionally not accumulated here
    pub decode_seconds: f64,
}

/// One decoded layer handed to the [`JitDecompressor::with_layers_decoded`]
/// consumer: a private arena plus per-tensor extents, in blob order.
#[derive(Default)]
pub struct LayerArena {
    buf: Vec<u8>,
    ends: Vec<usize>,
}

impl LayerArena {
    fn decode_layer(
        &mut self,
        blobs: &[&Ecf8Blob],
        pool: Option<&ThreadPool>,
        tables: &HashMap<Vec<u8>, Arc<DecodeTables>>,
    ) {
        self.ends.clear();
        let total: usize = blobs.iter().map(|b| b.n_elem).sum();
        if self.buf.len() < total {
            self.buf.resize(total, 0);
        }
        let mut off = 0usize;
        for blob in blobs {
            let t = tables
                .get(&blob.code_lengths)
                .expect("tables prebuilt for every code book");
            decode_into_cached(blob, &mut self.buf[off..off + blob.n_elem], pool, t);
            off += blob.n_elem;
            self.ends.push(off);
        }
    }

    /// Decoded bytes of the `i`-th blob of this layer.
    pub fn tensor(&self, i: usize) -> &[u8] {
        let start = if i == 0 { 0 } else { self.ends[i - 1] };
        &self.buf[start..self.ends[i]]
    }

    /// Number of tensors decoded into this arena.
    pub fn len(&self) -> usize {
        self.ends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ends.is_empty()
    }
}

/// JIT decompressor bound to a shared decode buffer.
pub struct JitDecompressor {
    buffer: DecodeBuffer,
    pool: Option<Arc<ThreadPool>>,
    stats: JitStats,
    /// decode tiers per canonical code book (keyed by stored lengths)
    tables: HashMap<Vec<u8>, Arc<DecodeTables>>,
    /// recycled decode-ahead ping-pong buffers, so steady-state
    /// [`Self::with_layers_decoded`] calls allocate nothing
    spare_arenas: Vec<LayerArena>,
}

impl JitDecompressor {
    /// `buffer_bytes` — the largest layer working-set in the model (the
    /// §3.3 buffer size); `pool` — optional block-parallel decode.
    pub fn new(buffer_bytes: usize, pool: Option<Arc<ThreadPool>>) -> Self {
        Self {
            buffer: DecodeBuffer::with_capacity(buffer_bytes),
            pool,
            stats: JitStats::default(),
            tables: HashMap::new(),
            spare_arenas: Vec::new(),
        }
    }

    /// Cached decode tiers for `blob`'s code book (built on first use).
    fn tables_for(&mut self, blob: &Ecf8Blob) -> Arc<DecodeTables> {
        self.tables
            .entry(blob.code_lengths.clone())
            .or_insert_with(|| Arc::new(DecodeTables::build(blob)))
            .clone()
    }

    /// Decode `blob` into the shared buffer and run `consume` on the
    /// decoded bytes (the layer execution). The buffer is free again when
    /// this returns.
    pub fn with_decoded<R>(&mut self, blob: &Ecf8Blob, consume: impl FnOnce(&[u8]) -> R) -> R {
        let t0 = std::time::Instant::now();
        let tables = self.tables_for(blob);
        let pool = self.pool.clone();
        let dst = self.buffer.slice_mut(blob.n_elem);
        decode_into_cached(blob, dst, pool.as_deref(), &tables);
        self.stats.tensors_decoded += 1;
        self.stats.bytes_decoded += blob.n_elem as u64;
        self.stats.decode_seconds += t0.elapsed().as_secs_f64();
        consume(self.buffer.slice(blob.n_elem))
    }

    /// Decode a set of tensors sequentially into the shared buffer,
    /// calling `consume` once per tensor (layer-by-layer order).
    pub fn for_each_decoded(
        &mut self,
        blobs: &[&Ecf8Blob],
        mut consume: impl FnMut(usize, &[u8]),
    ) {
        for (i, blob) in blobs.iter().enumerate() {
            self.with_decoded(blob, |bytes| consume(i, bytes));
        }
    }

    /// Start a new layer in arena mode: recycles the shared buffer.
    pub fn begin_layer(&mut self) {
        self.buffer.reset();
    }

    /// Decode `blob` into the arena and return its extent. Slices of all
    /// tensors decoded since [`Self::begin_layer`] stay simultaneously
    /// valid — index [`Self::arena`] with the returned ranges.
    pub fn decode_to_arena(&mut self, blob: &Ecf8Blob) -> Range<usize> {
        let t0 = std::time::Instant::now();
        let tables = self.tables_for(blob);
        let pool = self.pool.clone();
        let (range, dst) = self.buffer.alloc_mut(blob.n_elem);
        decode_into_cached(blob, dst, pool.as_deref(), &tables);
        self.stats.tensors_decoded += 1;
        self.stats.bytes_decoded += blob.n_elem as u64;
        self.stats.decode_seconds += t0.elapsed().as_secs_f64();
        range
    }

    /// The arena backing store (borrow with ranges from
    /// [`Self::decode_to_arena`]).
    pub fn arena(&self) -> &[u8] {
        self.buffer.bytes()
    }

    /// Decode-ahead over a sequence of layers: a background thread keeps
    /// one [`LayerArena`] decoded ahead of the consumer (two arenas
    /// ping-pong through channels), so layer ℓ+1's decode overlaps layer
    /// ℓ's `consume`. Returns the consumer's results, or its first error
    /// (the decoder thread winds down when the channels drop).
    pub fn with_layers_decoded<R, E>(
        &mut self,
        layers: &[Vec<&Ecf8Blob>],
        mut consume: impl FnMut(usize, &LayerArena) -> Result<R, E>,
    ) -> Result<Vec<R>, E> {
        // Build every code book's tiers up front so the decoder thread
        // only reads the cache.
        for layer in layers {
            for blob in layer {
                self.tables_for(blob);
            }
        }
        let tables = &self.tables;
        // double buffer: decode of layer l+1 overlaps consume(l); reuse
        // the buffers recovered from the previous call (steady state:
        // zero allocation on the request path)
        let mut seed_arenas = std::mem::take(&mut self.spare_arenas);
        seed_arenas.truncate(2);
        while seed_arenas.len() < 2 {
            seed_arenas.push(LayerArena::default());
        }
        let mut results = Vec::with_capacity(layers.len());
        let scope_out: Result<Vec<LayerArena>, E> = std::thread::scope(|s| {
            let (full_tx, full_rx) = mpsc::channel::<LayerArena>();
            let (free_tx, free_rx) = mpsc::channel::<LayerArena>();
            for arena in seed_arenas {
                free_tx.send(arena).expect("fresh channel");
            }
            let decoder = s.spawn(move || {
                for layer in layers {
                    // consumer hung up (error path) => stop decoding
                    let Ok(mut arena) = free_rx.recv() else {
                        return Vec::new();
                    };
                    arena.decode_layer(layer, None, tables);
                    if full_tx.send(arena).is_err() {
                        return Vec::new();
                    }
                }
                // recover the ping-pong buffers for the next call: drain
                // until the consumer drops its sender
                let mut leftover = Vec::new();
                while let Ok(arena) = free_rx.recv() {
                    leftover.push(arena);
                }
                leftover
            });
            for l in 0..layers.len() {
                let arena = full_rx.recv().expect("decoder thread alive");
                match consume(l, &arena) {
                    Ok(r) => results.push(r),
                    // dropping free_tx/full_rx unblocks the decoder (the
                    // recycled buffers are lost on this path — fine, the
                    // next call reallocates)
                    Err(e) => return Err(e),
                }
                let _ = free_tx.send(arena);
            }
            drop(free_tx);
            Ok(decoder.join().expect("decoder thread panicked"))
        });
        self.spare_arenas = scope_out?;
        for layer in layers {
            for blob in layer {
                self.stats.tensors_decoded += 1;
                self.stats.bytes_decoded += blob.n_elem as u64;
            }
        }
        Ok(results)
    }

    pub fn stats(&self) -> JitStats {
        self.stats
    }

    pub fn buffer_capacity(&self) -> usize {
        self.buffer.capacity()
    }

    /// Decode throughput so far (bytes of FP8 produced per second).
    pub fn decode_throughput_bps(&self) -> f64 {
        if self.stats.decode_seconds == 0.0 {
            return 0.0;
        }
        self.stats.bytes_decoded as f64 / self.stats.decode_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::compress_fp8;
    use crate::util::prng::Xoshiro256;

    fn blob(n: usize, seed: u64) -> (Vec<u8>, Ecf8Blob) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let data: Vec<u8> = (0..n)
            .map(|_| {
                let x = (crate::util::sampling::normal(&mut rng) * 0.05) as f32;
                crate::fp8::F8E4M3::from_f32(x).to_bits()
            })
            .collect();
        let b = compress_fp8(&data);
        (data, b)
    }

    #[test]
    fn decodes_bit_exact_into_shared_buffer() {
        let (d1, b1) = blob(10_000, 1);
        let (d2, b2) = blob(5_000, 2);
        let mut jit = JitDecompressor::new(10_000, None);
        jit.with_decoded(&b1, |bytes| assert_eq!(bytes, &d1[..]));
        jit.with_decoded(&b2, |bytes| assert_eq!(bytes, &d2[..]));
        assert_eq!(jit.stats().tensors_decoded, 2);
        assert_eq!(jit.stats().bytes_decoded, 15_000);
    }

    #[test]
    fn parallel_pool_gives_same_bytes() {
        let pool = Arc::new(ThreadPool::new(4));
        let (d, b) = blob(300_000, 3);
        let mut jit = JitDecompressor::new(300_000, Some(pool));
        jit.with_decoded(&b, |bytes| assert_eq!(bytes, &d[..]));
    }

    #[test]
    fn for_each_decoded_visits_in_order() {
        let (d1, b1) = blob(1000, 4);
        let (d2, b2) = blob(2000, 5);
        let mut jit = JitDecompressor::new(2000, None);
        let mut seen = Vec::new();
        jit.for_each_decoded(&[&b1, &b2], |i, bytes| {
            seen.push((i, bytes.len()));
            if i == 0 {
                assert_eq!(bytes, &d1[..]);
            } else {
                assert_eq!(bytes, &d2[..]);
            }
        });
        assert_eq!(seen, vec![(0, 1000), (1, 2000)]);
    }

    #[test]
    fn throughput_reported() {
        let (_, b) = blob(100_000, 6);
        let mut jit = JitDecompressor::new(100_000, None);
        jit.with_decoded(&b, |_| ());
        assert!(jit.decode_throughput_bps() > 0.0);
    }

    #[test]
    fn arena_holds_a_whole_layer_zero_copy() {
        let (d1, b1) = blob(10_000, 7);
        let (d2, b2) = blob(4_000, 8);
        let (d3, b3) = blob(6_000, 9);
        let mut jit = JitDecompressor::new(20_000, None);
        jit.begin_layer();
        let r1 = jit.decode_to_arena(&b1);
        let r2 = jit.decode_to_arena(&b2);
        let r3 = jit.decode_to_arena(&b3);
        // all three live at once, borrowed straight from the buffer
        let arena = jit.arena();
        assert_eq!(&arena[r1], &d1[..]);
        assert_eq!(&arena[r2], &d2[..]);
        assert_eq!(&arena[r3], &d3[..]);
        // recycling reuses the same memory
        jit.begin_layer();
        let r1b = jit.decode_to_arena(&b1);
        assert_eq!(r1b, 0..10_000);
        assert_eq!(&jit.arena()[r1b], &d1[..]);
    }

    #[test]
    fn decode_ahead_layers_bit_exact() {
        let (d1, b1) = blob(8_000, 10);
        let (d2, b2) = blob(3_000, 11);
        let (d3, b3) = blob(5_000, 12);
        let (d4, b4) = blob(1_000, 13);
        let mut jit = JitDecompressor::new(0, None);
        let layers: Vec<Vec<&Ecf8Blob>> = vec![vec![&b1, &b2], vec![&b3], vec![&b4]];
        let expect: Vec<Vec<&[u8]>> =
            vec![vec![&d1[..], &d2[..]], vec![&d3[..]], vec![&d4[..]]];
        let sizes = jit
            .with_layers_decoded(&layers, |l, arena| -> Result<usize, String> {
                assert_eq!(arena.len(), expect[l].len(), "layer {l}");
                for (i, want) in expect[l].iter().enumerate() {
                    assert_eq!(arena.tensor(i), *want, "layer {l} tensor {i}");
                }
                Ok(arena.tensor(0).len())
            })
            .unwrap();
        assert_eq!(sizes, vec![8_000, 3_000, 5_000]);
        assert_eq!(jit.stats().tensors_decoded, 4);
        assert_eq!(jit.stats().bytes_decoded, 17_000);
        // second pass reuses the recycled ping-pong arenas (steady-state
        // zero-allocation path) and stays bit-exact
        let again = jit
            .with_layers_decoded(&layers, |l, arena| -> Result<(), String> {
                for (i, want) in expect[l].iter().enumerate() {
                    assert_eq!(arena.tensor(i), *want, "pass 2 layer {l} tensor {i}");
                }
                Ok(())
            })
            .unwrap();
        assert_eq!(again.len(), 3);
        assert_eq!(jit.stats().tensors_decoded, 8);
    }

    #[test]
    fn decode_ahead_consumer_error_shuts_down_cleanly() {
        let (_, b1) = blob(2_000, 14);
        let (_, b2) = blob(2_000, 15);
        let mut jit = JitDecompressor::new(0, None);
        let layers: Vec<Vec<&Ecf8Blob>> = vec![vec![&b1], vec![&b2], vec![&b1]];
        let err = jit
            .with_layers_decoded(&layers, |l, _| -> Result<(), String> {
                if l == 1 {
                    Err("boom".to_string())
                } else {
                    Ok(())
                }
            })
            .unwrap_err();
        assert_eq!(err, "boom");
        // must return (not deadlock) and the decompressor stays usable
        jit.begin_layer();
        let r = jit.decode_to_arena(&b1);
        assert_eq!(r.len(), 2_000);
    }
}
