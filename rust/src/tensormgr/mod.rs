//! Tensor management (§3.3): just-in-time weight decompression with a
//! single pre-allocated buffer, plus the VRAM-offload device model used
//! by the DiT experiments (Table 3).

pub mod buffer;
pub mod jit;
pub mod offload;

pub use buffer::DecodeBuffer;
pub use jit::{decode_into_disjoint, JitDecompressor, LayerArena};
pub use offload::{DeviceModel, LayerStats, OffloadSim};
