//! VRAM-offload device model — the substrate for Table 3 (DiT inference
//! under DiffSynth-style VRAM management) and the capacity arithmetic of
//! Table 1 ("Supported Machine").
//!
//! The paper's DiT latency gains come from one mechanism (§4.2): offload
//! managers move weight components between host and device around every
//! denoising step, and ECF8 moves ~25 % fewer bytes. This module models
//! that pipeline: reload time = bytes / link bandwidth (+ decode time for
//! compressed weights, overlapped when the decoder outruns the link),
//! compute time = calibrated per-step cost.
//!
//! Bandwidths/capacities are the published SKU numbers (DESIGN.md
//! "Substitutions": capacity arithmetic is exact; bandwidth-bound
//! latencies reproduce ratios).

/// A GPU SKU: capacity and bandwidths.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceModel {
    pub name: &'static str,
    pub vram_bytes: u64,
    /// device memory bandwidth, bytes/s
    pub hbm_bps: f64,
    /// host↔device link bandwidth, bytes/s (PCIe or NVLink-C2C)
    pub link_bps: f64,
    /// sustained on-device ECF8 decode throughput, output bytes/s.
    /// The paper's kernel decodes at HBM-class rates; we use a
    /// conservative fraction of HBM bandwidth.
    pub decode_bps: f64,
}

const GB: u64 = 1_000_000_000;
const GBPS: f64 = 1e9;

/// The SKUs named in Tables 1–3.
pub fn device_zoo() -> Vec<DeviceModel> {
    fn dev(name: &'static str, vram_gb: u64, hbm: f64, link: f64) -> DeviceModel {
        DeviceModel {
            name,
            vram_bytes: vram_gb * GB,
            hbm_bps: hbm * GBPS,
            link_bps: link * GBPS,
            decode_bps: hbm * GBPS * 0.25,
        }
    }
    vec![
        dev("H100 (80 GB)", 80, 3350.0, 64.0),
        dev("H200 (141 GB)", 141, 4800.0, 64.0),
        dev("GH200 (96 GB)", 96, 4000.0, 450.0), // NVLink-C2C host link
        dev("RTX5090 (32 GB)", 32, 1790.0, 64.0),
        dev("RTX4090 (24 GB)", 24, 1008.0, 32.0),
        dev("RTX4080 (16 GB)", 16, 717.0, 32.0),
        dev("RTX4070 (12 GB)", 12, 504.0, 32.0),
    ]
}

pub fn device_by_name(name: &str) -> Option<DeviceModel> {
    device_zoo().into_iter().find(|d| d.name == name)
}

/// Smallest zoo device (by VRAM) on which `bytes` of weights fit with
/// `headroom_frac` of VRAM reserved for activations/KV — Table 1's
/// "Supported Machine" logic. `count` identical devices share the bytes.
pub fn smallest_supporting(bytes: u64, count: u64, headroom_frac: f64) -> Option<DeviceModel> {
    let mut zoo = device_zoo();
    zoo.sort_by_key(|d| d.vram_bytes);
    zoo.into_iter().find(|d| {
        let usable = (d.vram_bytes as f64 * (1.0 - headroom_frac)) * count as f64;
        bytes as f64 <= usable
    })
}

/// Per-transformer-layer byte totals of a packed model artifact — what
/// `model::store::LazyModel::layer_stats` reads straight out of the
/// container-v2 binary index (no tensor data touched). The lazy
/// per-layer load path reloads exactly these byte sets per denoising
/// step.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LayerStats {
    /// decoded FP8 bytes of the layer's tensors
    pub raw_bytes: u64,
    /// stored bytes of the layer's records (headers included)
    pub stored_bytes: u64,
}

/// One DiT serving configuration under VRAM management.
#[derive(Debug, Clone, Copy)]
pub struct OffloadSim {
    pub device: DeviceModel,
    /// total weight bytes moved per denoising step (the offloaded
    /// component set)
    pub reload_bytes_raw: u64,
    /// same weights in ECF8
    pub reload_bytes_compressed: u64,
    /// pure compute time per step, seconds (weights resident)
    pub compute_per_step_s: f64,
    pub n_steps: usize,
    /// largest single offloaded component (the decode staging buffer —
    /// §3.3: one pre-allocated buffer of the largest component's size)
    pub largest_component_bytes: u64,
}

/// Per-variant simulated result.
#[derive(Debug, Clone, Copy)]
pub struct OffloadResult {
    pub step_latency_s: f64,
    pub e2e_latency_s: f64,
    /// peak device memory: resident working set + staged component
    pub peak_memory_bytes: u64,
}

impl OffloadSim {
    /// Build the Table-3 reload simulation from a packed artifact's
    /// per-layer index stats (see [`LayerStats`]): the offloaded
    /// component set is every transformer layer, moved once per step;
    /// the staging buffer is the largest layer's decoded bytes (§3.3 —
    /// the lazy loader reloads one layer at a time through it).
    pub fn from_layer_stats(
        device: DeviceModel,
        layers: &[LayerStats],
        compute_per_step_s: f64,
        n_steps: usize,
    ) -> Self {
        Self {
            device,
            reload_bytes_raw: layers.iter().map(|l| l.raw_bytes).sum(),
            reload_bytes_compressed: layers.iter().map(|l| l.stored_bytes).sum(),
            compute_per_step_s,
            n_steps,
            largest_component_bytes: layers.iter().map(|l| l.raw_bytes).max().unwrap_or(0),
        }
    }

    /// Latency for the FP8 baseline: every step pays raw-bytes transfer.
    pub fn run_fp8(&self) -> OffloadResult {
        let transfer = self.reload_bytes_raw as f64 / self.device.link_bps;
        let step = transfer + self.compute_per_step_s;
        OffloadResult {
            step_latency_s: step,
            e2e_latency_s: step * self.n_steps as f64,
            peak_memory_bytes: self.reload_bytes_raw,
        }
    }

    /// Latency for ECF8: compressed bytes over the link, then on-device
    /// block-parallel decode; decode overlaps the next component's
    /// transfer, so the step pays max(transfer, decode) + compute, and
    /// peak memory holds compressed + decoded of the staged component.
    pub fn run_ecf8(&self) -> OffloadResult {
        let transfer = self.reload_bytes_compressed as f64 / self.device.link_bps;
        let decode = self.reload_bytes_raw as f64 / self.device.decode_bps;
        let step = transfer.max(decode) + self.compute_per_step_s;
        OffloadResult {
            step_latency_s: step,
            e2e_latency_s: step * self.n_steps as f64,
            // compressed weights stay resident; decode stages one
            // component at a time through the shared buffer
            peak_memory_bytes: self.reload_bytes_compressed + self.largest_component_bytes,
        }
    }

    /// (latency ↓ %, memory ↓ %) of ECF8 vs FP8 — Table 3's last columns.
    pub fn improvement(&self) -> (f64, f64) {
        let fp8 = self.run_fp8();
        let ecf8 = self.run_ecf8();
        (
            (1.0 - ecf8.e2e_latency_s / fp8.e2e_latency_s) * 100.0,
            (1.0 - ecf8.peak_memory_bytes as f64 / fp8.peak_memory_bytes as f64) * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_covers_paper_skus() {
        let names: Vec<&str> = device_zoo().iter().map(|d| d.name).collect();
        for want in [
            "H100 (80 GB)",
            "H200 (141 GB)",
            "GH200 (96 GB)",
            "RTX4070 (12 GB)",
            "RTX4090 (24 GB)",
        ] {
            assert!(names.contains(&want), "{want}");
        }
    }

    #[test]
    fn smallest_supporting_matches_table1_cases() {
        // Wan2.1: 17.40 GB raw exceeds RTX4080 16GB budget with headroom;
        // 12.65 GB compressed fits (the paper's example)
        let raw = smallest_supporting(17_400_000_000, 1, 0.15).unwrap();
        let comp = smallest_supporting(12_650_000_000, 1, 0.15).unwrap();
        assert!(comp.vram_bytes <= raw.vram_bytes);
        assert_eq!(comp.name, "RTX4080 (16 GB)");
        // Qwen3-8B: 5.61 GB fits the 12 GB card
        assert_eq!(
            smallest_supporting(5_610_000_000, 1, 0.15).unwrap().name,
            "RTX4070 (12 GB)"
        );
    }

    #[test]
    fn nothing_supports_absurd_sizes() {
        assert!(smallest_supporting(10_000 * GB, 1, 0.1).is_none());
    }

    #[test]
    fn ecf8_offload_is_faster_and_smaller() {
        let sim = OffloadSim {
            device: device_by_name("GH200 (96 GB)").unwrap(),
            reload_bytes_raw: 10 * GB,
            reload_bytes_compressed: 8 * GB,
            compute_per_step_s: 0.2,
            n_steps: 30,
            largest_component_bytes: GB,
        };
        let fp8 = sim.run_fp8();
        let ecf8 = sim.run_ecf8();
        assert!(ecf8.e2e_latency_s < fp8.e2e_latency_s);
        assert!(ecf8.peak_memory_bytes < fp8.peak_memory_bytes);
        let (lat_down, mem_down) = sim.improvement();
        assert!(lat_down > 0.0 && mem_down > 0.0);
    }

    #[test]
    fn from_layer_stats_aggregates_the_index_view() {
        let layers = [
            LayerStats {
                raw_bytes: 4 * GB,
                stored_bytes: 3 * GB,
            },
            LayerStats {
                raw_bytes: 6 * GB,
                stored_bytes: 5 * GB,
            },
        ];
        let sim = OffloadSim::from_layer_stats(
            device_by_name("GH200 (96 GB)").unwrap(),
            &layers,
            0.3,
            10,
        );
        assert_eq!(sim.reload_bytes_raw, 10 * GB);
        assert_eq!(sim.reload_bytes_compressed, 8 * GB);
        assert_eq!(sim.largest_component_bytes, 6 * GB);
        let (lat_down, mem_down) = sim.improvement();
        assert!(lat_down > 0.0 && mem_down > 0.0);
    }

    #[test]
    fn compute_bound_models_show_small_gains() {
        // Wan-style: compute dominates -> latency gain is small (the
        // paper's 3-4 % observation)
        let sim = OffloadSim {
            device: device_by_name("GH200 (96 GB)").unwrap(),
            reload_bytes_raw: 17 * GB,
            reload_bytes_compressed: 12 * GB,
            compute_per_step_s: 9.0,
            n_steps: 50,
            largest_component_bytes: 2 * GB,
        };
        let (lat_down, _) = sim.improvement();
        assert!(lat_down > 0.0 && lat_down < 10.0, "{lat_down}");
    }
}
