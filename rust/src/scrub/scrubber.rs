//! The paced CRC scrubber and the index-driven repair path.
//!
//! A [`Scrubber`] thread walks a packed store re-verifying every
//! record's payload CRC against the bytes on disk at a configurable
//! bytes/sec budget (so a month-long background pass never competes
//! with serving for disk bandwidth), repairs what it finds from the
//! parity sidecars, and quarantines only what parity cannot recover.
//!
//! Repair is **index-driven**, not walk-driven: `walk_shard` stops at
//! the first corrupt record, but the index is independently
//! CRC-protected and knows every record's exact offset and length, so
//! corruption maps directly to erased FEC symbols. Repaired shards are
//! written to a tmp file and renamed over the original — the same
//! no-SIGBUS discipline as every other artifact commit: a mapped reader
//! keeps serving the old inode and simply sees the repaired bytes on
//! its next open (or its decode-time retry re-reads the committed file
//! directly).

use super::parity::{bad_ranges, load_sidecar, verify_entry};
use crate::codec::container::{self, shard_file_name, TensorIndex, INDEX_FILE};
use crate::coordinator::metrics::SharedScrubMetrics;
use crate::model::store::{repair_scan, QuarantinedRecord, RepairReport};
use crate::scheduler::Clock;
use crate::telemetry::recorder::{DumpReason, FlightEvent, FlightRecorder};
use crate::util::crc32::crc32;
use anyhow::{Context, Result};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Pacing
// ---------------------------------------------------------------------------

/// Token-bucket-free pacing: after `note(bytes)` the caller owes a sleep
/// long enough that cumulative scanned bytes never run ahead of
/// `bytes_per_sec × elapsed`. Time comes from the injected [`Clock`], so
/// the schedule is exact and deterministic under `SimClock` — the unit
/// tests assert the sleep sequence to the microsecond.
pub struct Pacer {
    clock: Arc<dyn Clock>,
    bytes_per_sec: u64,
    start: Instant,
    consumed: u64,
}

impl Pacer {
    /// `bytes_per_sec == 0` disables pacing (every delay is zero).
    pub fn new(clock: Arc<dyn Clock>, bytes_per_sec: u64) -> Self {
        let start = clock.now();
        Self {
            clock,
            bytes_per_sec,
            start,
            consumed: 0,
        }
    }

    /// Account `bytes` of work; returns how long the caller must sleep
    /// before doing more.
    pub fn note(&mut self, bytes: u64) -> Duration {
        self.consumed = self.consumed.saturating_add(bytes);
        if self.bytes_per_sec == 0 {
            return Duration::ZERO;
        }
        let earliest = self.start
            + Duration::from_secs_f64(self.consumed as f64 / self.bytes_per_sec as f64);
        let now = self.clock.now();
        earliest.checked_duration_since(now).unwrap_or(Duration::ZERO)
    }

    pub fn consumed(&self) -> u64 {
        self.consumed
    }
}

// ---------------------------------------------------------------------------
// Index-driven repair
// ---------------------------------------------------------------------------

/// One record the repair path restored from parity.
#[derive(Debug, Clone)]
pub struct RepairedRecord {
    pub tensor: String,
    pub shard: u32,
    pub offset: u64,
    /// what the verifier saw before repair
    pub reason: String,
}

/// Outcome of repairing one shard in place on disk.
#[derive(Debug, Default)]
pub struct ShardRepair {
    pub repaired: Vec<RepairedRecord>,
    pub unrecoverable: Vec<QuarantinedRecord>,
    /// a repaired shard file was committed (tmp+rename)
    pub committed: bool,
    /// committed bytes hash to the sidecar's pristine CRC — the
    /// byte-identity oracle, stronger than per-record consistency
    pub identical: bool,
}

/// Everything [`repair_store`] did: the damage it walked in with, what
/// it fixed, what it had to give up on, and the state it left behind.
#[derive(Debug)]
pub struct StoreRepairOutcome {
    pub before: RepairReport,
    pub repaired: Vec<RepairedRecord>,
    pub unrecoverable: Vec<QuarantinedRecord>,
    /// post-repair scan (also rewrites the quarantine sidecar)
    pub after: RepairReport,
}

impl StoreRepairOutcome {
    /// Every layer (and embed/head) serves after repair.
    pub fn fully_servable(&self) -> bool {
        self.after.is_clean()
    }
}

fn quarantine_all(
    shard: u32,
    bad: &[(Option<String>, Range<u64>)],
    reason: &str,
) -> Vec<QuarantinedRecord> {
    bad.iter()
        .map(|(name, range)| QuarantinedRecord {
            tensor: name
                .clone()
                .unwrap_or_else(|| "<shard-header>".to_string()),
            shard,
            offset: range.start,
            len: range.end - range.start,
            reason: reason.to_string(),
        })
        .collect()
}

/// Repair one shard on disk from its parity sidecar. Reads the shard
/// (tolerating truncation — the missing tail becomes erased symbols),
/// finds every bad byte range via the index, erases + recovers through
/// the sidecar's RS blocks, re-verifies every record, and commits the
/// repaired image tmp+rename. Never mutates the existing file in place.
pub fn repair_shard(dir: &Path, index: &TensorIndex, shard: u32) -> Result<ShardRepair> {
    let mut out = ShardRepair::default();
    let path = dir.join(shard_file_name(shard));
    let mut bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) => {
            // nothing to splice parity into: record-level parity cannot
            // rebuild a whole missing file
            out.unrecoverable.push(QuarantinedRecord {
                tensor: "<shard-wide>".to_string(),
                shard,
                offset: 0,
                len: 0,
                reason: format!("unreadable ({e}); parity cannot rebuild a missing shard"),
            });
            return Ok(out);
        }
    };
    let sidecar = match load_sidecar(dir, shard) {
        Ok(Some(sc)) => sc,
        Ok(None) => {
            let bad = bad_ranges(index, shard, &bytes);
            out.unrecoverable =
                quarantine_all(shard, &bad, "no parity sidecar (pack with --parity)");
            return Ok(out);
        }
        Err(e) => {
            let bad = bad_ranges(index, shard, &bytes);
            out.unrecoverable =
                quarantine_all(shard, &bad, &format!("parity sidecar unusable: {e}"));
            return Ok(out);
        }
    };

    // torn writes: pad a truncated shard back to its pristine length
    // (the tail is erased symbols), drop bytes past it
    let pristine_len = sidecar.shard_len as usize;
    let mut bad: Vec<(Option<String>, Range<u64>)> = Vec::new();
    if bytes.len() < pristine_len {
        bad.push((None, bytes.len() as u64..pristine_len as u64));
        bytes.resize(pristine_len, 0);
    } else if bytes.len() > pristine_len {
        bytes.truncate(pristine_len);
    }
    bad.extend(bad_ranges(index, shard, &bytes));
    if bad.is_empty() {
        return Ok(out); // clean shard, nothing to do
    }

    // partial repair is in-place: recoverable blocks are restored even
    // when sibling blocks are beyond budget; the re-verification pass
    // below attributes per record which is which
    let ranges: Vec<Range<u64>> = bad.iter().map(|(_, r)| r.clone()).collect();
    let _ = sidecar.repair(&mut bytes, &ranges);

    // attribution pass: which of the previously-bad records verify now?
    let mut still_bad = false;
    for (name, range) in &bad {
        let verified = match name {
            Some(tensor) => index
                .entries
                .iter()
                .find(|e| e.shard == shard && &e.name == tensor)
                .map(|e| verify_entry(&bytes, e).map_err(|r| r.to_string()))
                .unwrap_or(Err("entry vanished from index".to_string())),
            None => match container::parse_shard_header(&bytes) {
                Ok(claimed) if claimed as u32 == shard => Ok(()),
                Ok(claimed) => Err(format!("shard claims index {claimed}")),
                Err(e) => Err(format!("bad shard header: {e}")),
            },
        };
        match verified {
            Ok(()) => out.repaired.push(RepairedRecord {
                tensor: name.clone().unwrap_or_else(|| "<shard-header>".to_string()),
                shard,
                offset: range.start,
                reason: "restored from parity sidecar".to_string(),
            }),
            Err(reason) => {
                still_bad = true;
                out.unrecoverable.push(QuarantinedRecord {
                    tensor: name.clone().unwrap_or_else(|| "<shard-header>".to_string()),
                    shard,
                    offset: range.start,
                    len: range.end - range.start,
                    reason: format!("beyond parity budget: {reason}"),
                });
            }
        }
    }

    out.identical = !still_bad && crc32(&bytes) == sidecar.shard_crc;
    if !still_bad && !out.identical {
        // every record verifies but the file hash deviates — refuse to
        // commit a store we cannot prove identical (defense in depth;
        // records cover the whole file, so this should be unreachable)
        for r in out.repaired.drain(..) {
            out.unrecoverable.push(QuarantinedRecord {
                tensor: r.tensor,
                shard,
                offset: r.offset,
                len: 0,
                reason: "repaired records verify but shard hash deviates".to_string(),
            });
        }
        return Ok(out);
    }
    if out.repaired.is_empty() {
        return Ok(out); // nothing improved; keep the original inode
    }

    // commit: tmp + unlink + rename — a live mapping of the old file
    // keeps its inode (no SIGBUS), new opens see the repaired bytes
    let tmp = dir.join(format!("{}.tmp", shard_file_name(shard)));
    std::fs::write(&tmp, &bytes).with_context(|| format!("writing {}", tmp.display()))?;
    let _ = std::fs::remove_file(&path);
    std::fs::rename(&tmp, &path).with_context(|| format!("committing {}", path.display()))?;
    out.committed = true;
    Ok(out)
}

/// Scan + repair + re-scan a whole store. The closing scan rewrites the
/// quarantine sidecar so it reflects only what parity could not fix.
pub fn repair_store(dir: &Path) -> Result<StoreRepairOutcome> {
    let before = repair_scan(dir, false)?;
    let mut repaired = Vec::new();
    let mut unrecoverable = Vec::new();
    if !before.is_clean() {
        let index_bytes = std::fs::read(dir.join(INDEX_FILE))
            .with_context(|| format!("reading {} in {}", INDEX_FILE, dir.display()))?;
        let index = TensorIndex::deserialize(&index_bytes)?;
        let mut shards: Vec<u32> = before
            .quarantined
            .iter()
            .map(|q| q.shard)
            .chain(before.missing_shards.iter().copied())
            .collect();
        shards.sort_unstable();
        shards.dedup();
        for s in shards {
            let r = repair_shard(dir, &index, s)?;
            repaired.extend(r.repaired);
            unrecoverable.extend(r.unrecoverable);
        }
    }
    let after = repair_scan(dir, true)?;
    Ok(StoreRepairOutcome {
        before,
        repaired,
        unrecoverable,
        after,
    })
}

// ---------------------------------------------------------------------------
// The background scrubber
// ---------------------------------------------------------------------------

/// Scrubber tuning.
#[derive(Debug, Clone, Copy)]
pub struct ScrubConfig {
    /// verification read budget; 0 = unpaced
    pub bytes_per_sec: u64,
    /// idle time between passes
    pub interval: Duration,
    /// stop after this many passes (`None` = run until [`Scrubber::stop`])
    pub max_passes: Option<u64>,
}

impl Default for ScrubConfig {
    fn default() -> Self {
        Self {
            bytes_per_sec: 8 << 20, // 8 MiB/s: background, not a burst
            interval: Duration::from_secs(60),
            max_passes: None,
        }
    }
}

/// One completed scrub pass.
#[derive(Debug, Default)]
pub struct ScrubPassReport {
    pub records: u64,
    pub clean: u64,
    pub bytes_scanned: u64,
    pub repaired: Vec<RepairedRecord>,
    pub unrecoverable: Vec<QuarantinedRecord>,
    pub duration: Duration,
}

/// Verify every record of the store at `dir` against the bytes on disk,
/// pacing reads through `pacer`, and route any damage through
/// [`repair_store`]. Reads go through `std::fs` (never the page cache of
/// a live mapping) so the scrubber observes what a fresh open would.
pub fn scrub_pass(
    dir: &Path,
    pacer: &mut Pacer,
    stop: Option<&StopFlag>,
) -> Result<ScrubPassReport> {
    let started = pacer.clock.now();
    let mut report = ScrubPassReport::default();
    let index_bytes = std::fs::read(dir.join(INDEX_FILE))
        .with_context(|| format!("reading {} in {}", INDEX_FILE, dir.display()))?;
    let index = TensorIndex::deserialize(&index_bytes)?;
    let mut damage = false;
    'shards: for s in 0..index.n_shards {
        let path = dir.join(shard_file_name(s));
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(_) => {
                damage = true;
                continue;
            }
        };
        report.bytes_scanned += bytes.len() as u64;
        if !matches!(container::parse_shard_header(&bytes), Ok(c) if c as u32 == s) {
            damage = true;
        }
        for e in index.entries.iter().filter(|e| e.shard == s) {
            report.records += 1;
            match verify_entry(&bytes, e) {
                Ok(()) => report.clean += 1,
                Err(_) => damage = true,
            }
            let delay = pacer.note(e.len);
            if !sleep_interruptible(delay, stop) {
                break 'shards;
            }
        }
    }
    if damage {
        let outcome = repair_store(dir)?;
        report.repaired = outcome.repaired;
        report.unrecoverable = outcome.unrecoverable;
    }
    report.duration = pacer.clock.now().saturating_duration_since(started);
    Ok(report)
}

/// Shared stop signal: a condvar-paired flag so interval sleeps and
/// pacing sleeps both wake immediately on [`Scrubber::stop`].
pub struct StopFlag {
    flag: Mutex<bool>,
    cv: Condvar,
}

impl StopFlag {
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            flag: Mutex::new(false),
            cv: Condvar::new(),
        })
    }

    pub fn raise(&self) {
        *self.flag.lock().unwrap() = true;
        self.cv.notify_all();
    }

    pub fn raised(&self) -> bool {
        *self.flag.lock().unwrap()
    }

    /// Sleep up to `d` or until raised; true = keep going.
    fn sleep(&self, d: Duration) -> bool {
        let guard = self.flag.lock().unwrap();
        if *guard {
            return false;
        }
        if d.is_zero() {
            return true;
        }
        let (guard, _) = self.cv.wait_timeout(guard, d).unwrap();
        !*guard
    }
}

/// `true` = continue, `false` = stop requested mid-sleep.
fn sleep_interruptible(d: Duration, stop: Option<&StopFlag>) -> bool {
    match stop {
        Some(s) => s.sleep(d),
        None => {
            if !d.is_zero() {
                thread::sleep(d);
            }
            true
        }
    }
}

/// The background scrubber thread. Spawn it next to a serving stack;
/// progress and repair counts flow out through the shared
/// [`ScrubMetrics`](crate::coordinator::metrics::ScrubMetrics) so the
/// supervisor's `HealthReport` can include scrub status without
/// touching the thread.
pub struct Scrubber {
    stop: Arc<StopFlag>,
    handle: Option<thread::JoinHandle<Result<()>>>,
    metrics: SharedScrubMetrics,
}

impl Scrubber {
    pub fn spawn(
        dir: PathBuf,
        cfg: ScrubConfig,
        clock: Arc<dyn Clock>,
        metrics: SharedScrubMetrics,
    ) -> Self {
        Self::spawn_with_recorder(dir, cfg, clock, metrics, None)
    }

    /// Like [`Self::spawn`], with a shared flight recorder: every pass
    /// that touched damage records a `Repair` event, and a pass that
    /// left anything unrecoverable dumps a postmortem on the spot (the
    /// scrubber loop is its own safe point — the pass is fully
    /// bookkept when it triggers).
    pub fn spawn_with_recorder(
        dir: PathBuf,
        cfg: ScrubConfig,
        clock: Arc<dyn Clock>,
        metrics: SharedScrubMetrics,
        recorder: Option<Arc<FlightRecorder>>,
    ) -> Self {
        let stop = StopFlag::new();
        let (stop2, metrics2) = (Arc::clone(&stop), metrics.clone());
        let handle = thread::Builder::new()
            .name("ecf8-scrubber".into())
            .spawn(move || -> Result<()> {
                let mut passes = 0u64;
                loop {
                    let mut pacer = Pacer::new(Arc::clone(&clock), cfg.bytes_per_sec);
                    let report = scrub_pass(&dir, &mut pacer, Some(&stop2))?;
                    metrics2.record_pass(
                        report.records,
                        report.bytes_scanned,
                        report.repaired.len() as u64,
                        report.unrecoverable.len() as u64,
                        report.duration.as_secs_f64(),
                    );
                    if let Some(rc) = &recorder {
                        let repaired = report.repaired.len() as u64;
                        let unrecoverable = report.unrecoverable.len() as u64;
                        if repaired > 0 || unrecoverable > 0 {
                            rc.record(FlightEvent::Repair {
                                repaired,
                                unrecoverable,
                            });
                        }
                        if unrecoverable > 0 {
                            rc.trigger(DumpReason::UnrecoverableRepair);
                            rc.flush();
                        }
                    }
                    passes += 1;
                    if stop2.raised() || cfg.max_passes.is_some_and(|m| passes >= m) {
                        return Ok(());
                    }
                    if !stop2.sleep(cfg.interval) {
                        return Ok(());
                    }
                }
            })
            .expect("spawn scrubber thread");
        Self {
            stop,
            handle: Some(handle),
            metrics,
        }
    }

    /// Live metrics snapshot (also reachable through the shared handle
    /// given to `spawn`).
    pub fn metrics(&self) -> crate::coordinator::metrics::ScrubMetrics {
        self.metrics.snapshot()
    }

    /// Signal, join, and return the final metrics. Propagates an I/O
    /// error from the scrub loop (corruption itself is never an error —
    /// it becomes repair/quarantine counts).
    pub fn stop(mut self) -> Result<crate::coordinator::metrics::ScrubMetrics> {
        self.stop.raise();
        if let Some(h) = self.handle.take() {
            match h.join() {
                Ok(r) => r?,
                Err(_) => anyhow::bail!("scrubber thread panicked"),
            }
        }
        Ok(self.metrics.snapshot())
    }
}

impl Drop for Scrubber {
    fn drop(&mut self) {
        self.stop.raise();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::SimClock;

    #[test]
    fn pacer_schedule_is_deterministic_under_simclock() {
        let clock = SimClock::new();
        let mut p = Pacer::new(clock.clone(), 1000); // 1000 B/s
        // 500 bytes at t=0 → owe 0.5 s
        assert_eq!(p.note(500), Duration::from_millis(500));
        // time passes 0.5 s → caught up exactly
        clock.advance(Duration::from_millis(500));
        assert_eq!(p.note(0), Duration::ZERO);
        // 250 more bytes → owe 0.25 s
        assert_eq!(p.note(250), Duration::from_millis(250));
        // advancing past the debt clamps to zero
        clock.advance(Duration::from_secs(10));
        assert_eq!(p.note(1000), Duration::ZERO);
        assert_eq!(p.consumed(), 1750);
    }

    #[test]
    fn pacer_zero_budget_never_sleeps() {
        let clock = SimClock::new();
        let mut p = Pacer::new(clock, 0);
        for _ in 0..100 {
            assert_eq!(p.note(u64::MAX / 200), Duration::ZERO);
        }
    }

    #[test]
    fn stop_flag_interrupts_sleep() {
        let stop = StopFlag::new();
        stop.raise();
        assert!(!stop.sleep(Duration::from_secs(3600)));
    }
}
