//! Parity sidecars: local Reed–Solomon repair data for a packed store.
//!
//! `shard-NNNN.ecf8p` sits next to its shard and holds, per
//! record-aligned source block (the same [`plan_shard_blocks`]
//! decomposition the fleet sender streams), the block's FEC geometry and
//! its `parity` repair symbols. Any corrupt byte range that erases at
//! most `parity` symbols of a block is reconstructible *locally* — no
//! re-download — from the ≥ k surviving symbols.
//!
//! ## Sidecar layout (`ECSP`, version 1, little-endian)
//!
//! ```text
//! offset  field           type
//! 0       magic           [u8; 4]  = "ECSP"
//! 4       version         u16      = 1
//! 6       shard_index     u16
//! 8       fec id          u8
//! 9       pad             [u8; 3]  = 0
//! 12      n_blocks        u32
//! 16      shard_len       u64      pristine shard file length
//! 24      shard_crc       u32      CRC-32 of the pristine shard file
//! 28      reserved        u32      = 0
//! 32      block table     n_blocks × 24:
//!           block u32 | offset u64 | len u32 | k u16 | parity u16
//!           | symbol_bytes u32
//! ...     per block, in table order:
//!           k × u32                  source-symbol CRC-32s
//!           parity × symbol_bytes    parity symbols
//! tail    crc32           u32      over every preceding byte
//! ```
//!
//! `shard_crc` is the post-repair identity oracle: a fully repaired
//! shard must hash back to the pristine CRC, so a "repaired" store is
//! *provably* byte-identical to the store that was protected, not merely
//! record-CRC-consistent.
//!
//! The per-symbol CRCs are what make record-level damage reports
//! repairable at all: the index can only attribute corruption to a
//! whole record ("this record's payload CRC fails"), and a typical
//! record spans more symbols than a block's parity budget. Erasing
//! every symbol a bad record touches would routinely be beyond budget
//! for a single flipped bit. Instead [`ParitySidecar::repair`] uses the
//! caller's bad ranges only to pick which blocks to examine, then
//! localizes erasures inside each block by re-hashing its source
//! symbols against the stored CRCs — one flipped byte erases one
//! symbol, not its whole record.

use crate::codec::container::{self, RecordHeader, SHARD_HEADER_BYTES};
use crate::distribution::fec::MAX_TOTAL_SYMBOLS;
use crate::distribution::sender::{plan_shard_blocks, BlockPlan, SenderConfig, StreamPlan};
use crate::distribution::{fec_for, DistError, FecId, FecParams};
use crate::util::crc32::crc32;
use std::fmt;
use std::ops::Range;
use std::path::{Path, PathBuf};

pub const PARITY_MAGIC: [u8; 4] = *b"ECSP";
pub const PARITY_VERSION: u16 = 1;
/// fixed header bytes before the block table
pub const PARITY_HEADER_BYTES: usize = 32;
/// bytes per block-table row
pub const PARITY_BLOCK_ROW_BYTES: usize = 24;

/// `shard-0007.ecf8s` → `shard-0007.ecf8p`.
pub fn parity_file_name(shard: u32) -> String {
    format!("shard-{shard:04}.ecf8p")
}

/// Structured failures of the sidecar/repair layer. Everything here is a
/// *detected* condition — corruption never surfaces as a panic or as
/// silently wrong bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScrubError {
    BadMagic,
    BadVersion(u16),
    Truncated { need: usize, have: usize },
    CrcMismatch { stored: u32, computed: u32 },
    /// sidecar disagrees with the shard it claims to protect
    Stale(String),
    /// block geometry in the table fails [`FecParams`] validation
    BadGeometry(String),
    /// more symbols erased than parity can rebuild
    Unrecoverable { block: u32, have: usize, need: usize },
    Fec(DistError),
    Io(String),
}

impl fmt::Display for ScrubError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScrubError::BadMagic => write!(f, "bad parity sidecar magic"),
            ScrubError::BadVersion(v) => write!(f, "unsupported sidecar version {v}"),
            ScrubError::Truncated { need, have } => {
                write!(f, "sidecar truncated: need {need} bytes, have {have}")
            }
            ScrubError::CrcMismatch { stored, computed } => write!(
                f,
                "sidecar CRC mismatch (stored {stored:#010x}, computed {computed:#010x})"
            ),
            ScrubError::Stale(why) => write!(f, "sidecar stale: {why}"),
            ScrubError::BadGeometry(why) => write!(f, "bad block geometry: {why}"),
            ScrubError::Unrecoverable { block, have, need } => write!(
                f,
                "block {block} unrecoverable: {have} symbols survive, {need} needed"
            ),
            ScrubError::Fec(e) => write!(f, "fec: {e}"),
            ScrubError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ScrubError {}

impl From<DistError> for ScrubError {
    fn from(e: DistError) -> Self {
        ScrubError::Fec(e)
    }
}

/// One protected block: its plan (offset/len/geometry) plus the encoded
/// parity symbols.
#[derive(Debug, Clone)]
pub struct ParityBlock {
    pub plan: BlockPlan,
    /// CRC-32 of each of the `plan.params.k` pristine source symbols
    /// (post-padding) — the erasure localizer
    pub source_crcs: Vec<u32>,
    /// `plan.params.parity` symbols, each `symbol_bytes` long
    pub parity: Vec<Vec<u8>>,
}

impl ParityBlock {
    fn byte_range(&self) -> Range<u64> {
        self.plan.offset..self.plan.offset + self.plan.len as u64
    }
}

/// In-memory form of one `shard-NNNN.ecf8p` file.
#[derive(Debug, Clone)]
pub struct ParitySidecar {
    pub shard: u16,
    pub fec: FecId,
    /// pristine shard file length
    pub shard_len: u64,
    /// CRC-32 of the pristine shard file — the repair identity oracle
    pub shard_crc: u32,
    pub blocks: Vec<ParityBlock>,
}

/// Split one block's bytes into `k` source symbols of `sym` bytes, the
/// last zero-padded — byte-for-byte the sender's symbolization, so the
/// sidecar's parity is interchangeable with wire parity.
fn symbolize(raw: &[u8], params: &FecParams) -> Vec<Vec<u8>> {
    let (k, sym) = (params.k as usize, params.symbol_bytes as usize);
    (0..k)
        .map(|i| {
            let lo = i * sym;
            let hi = ((i + 1) * sym).min(raw.len());
            let mut s = raw[lo..hi.max(lo)].to_vec();
            s.resize(sym, 0);
            s
        })
        .collect()
}

impl ParitySidecar {
    /// Encode parity for a pristine shard. The block decomposition is the
    /// sender's record-aligned plan, so parity never straddles a record
    /// arbitrarily: each block closes on a record boundary and the 8-byte
    /// shard header rides with the first block (a flipped header bit is
    /// repairable too). Refuses [`FecId::NoCode`] — a sidecar with no
    /// parity protects nothing.
    pub fn build(shard: u16, data: &[u8], cfg: &SenderConfig) -> Result<Self, ScrubError> {
        if cfg.fec == FecId::NoCode {
            return Err(ScrubError::BadGeometry("NoCode carries no parity".into()));
        }
        let codec = fec_for(cfg.fec.as_u8()).ok_or(DistError::UnknownFec(cfg.fec.as_u8()))?;
        let plan: StreamPlan = plan_shard_blocks(shard, data, cfg)?;
        let mut blocks = Vec::with_capacity(plan.blocks.len());
        for b in plan.blocks {
            let raw = &data[b.offset as usize..(b.offset + b.len as u64) as usize];
            let source = symbolize(raw, &b.params);
            let parity = codec.encode_parity(&b.params, &source)?;
            let source_crcs = source.iter().map(|s| crc32(s)).collect();
            blocks.push(ParityBlock {
                plan: b,
                source_crcs,
                parity,
            });
        }
        Ok(Self {
            shard,
            fec: cfg.fec,
            shard_len: data.len() as u64,
            shard_crc: crc32(data),
            blocks,
        })
    }

    /// Total parity payload bytes (the sidecar's storage overhead, table
    /// and framing excluded).
    pub fn parity_bytes(&self) -> u64 {
        self.blocks
            .iter()
            .map(|b| b.parity.len() as u64 * b.plan.params.symbol_bytes as u64)
            .sum()
    }

    pub fn serialize(&self) -> Vec<u8> {
        let crc_table_bytes: usize = self.blocks.iter().map(|b| b.source_crcs.len() * 4).sum();
        let mut out = Vec::with_capacity(
            PARITY_HEADER_BYTES
                + self.blocks.len() * PARITY_BLOCK_ROW_BYTES
                + crc_table_bytes
                + self.parity_bytes() as usize
                + 4,
        );
        out.extend_from_slice(&PARITY_MAGIC);
        out.extend_from_slice(&PARITY_VERSION.to_le_bytes());
        out.extend_from_slice(&self.shard.to_le_bytes());
        out.push(self.fec.as_u8());
        out.extend_from_slice(&[0u8; 3]);
        out.extend_from_slice(&(self.blocks.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.shard_len.to_le_bytes());
        out.extend_from_slice(&self.shard_crc.to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes());
        for b in &self.blocks {
            out.extend_from_slice(&b.plan.block.to_le_bytes());
            out.extend_from_slice(&b.plan.offset.to_le_bytes());
            out.extend_from_slice(&b.plan.len.to_le_bytes());
            out.extend_from_slice(&b.plan.params.k.to_le_bytes());
            out.extend_from_slice(&b.plan.params.parity.to_le_bytes());
            out.extend_from_slice(&b.plan.params.symbol_bytes.to_le_bytes());
        }
        for b in &self.blocks {
            for c in &b.source_crcs {
                out.extend_from_slice(&c.to_le_bytes());
            }
            for p in &b.parity {
                out.extend_from_slice(p);
            }
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn deserialize(data: &[u8]) -> Result<Self, ScrubError> {
        let need = |n: usize| -> Result<(), ScrubError> {
            if data.len() < n {
                Err(ScrubError::Truncated {
                    need: n,
                    have: data.len(),
                })
            } else {
                Ok(())
            }
        };
        need(PARITY_HEADER_BYTES + 4)?;
        let body = &data[..data.len() - 4];
        let stored = u32::from_le_bytes(data[data.len() - 4..].try_into().expect("4 bytes"));
        let computed = crc32(body);
        if stored != computed {
            return Err(ScrubError::CrcMismatch { stored, computed });
        }
        if body[..4] != PARITY_MAGIC {
            return Err(ScrubError::BadMagic);
        }
        let version = u16::from_le_bytes(body[4..6].try_into().expect("2 bytes"));
        if version != PARITY_VERSION {
            return Err(ScrubError::BadVersion(version));
        }
        let shard = u16::from_le_bytes(body[6..8].try_into().expect("2 bytes"));
        let fec = FecId::from_u8(body[8]).ok_or(ScrubError::Fec(DistError::UnknownFec(body[8])))?;
        let n_blocks = u32::from_le_bytes(body[12..16].try_into().expect("4 bytes")) as usize;
        let shard_len = u64::from_le_bytes(body[16..24].try_into().expect("8 bytes"));
        let shard_crc = u32::from_le_bytes(body[24..28].try_into().expect("4 bytes"));
        let table_end = PARITY_HEADER_BYTES + n_blocks * PARITY_BLOCK_ROW_BYTES;
        need(table_end + 4)?;
        let mut blocks = Vec::with_capacity(n_blocks);
        for i in 0..n_blocks {
            let row = &body[PARITY_HEADER_BYTES + i * PARITY_BLOCK_ROW_BYTES..];
            let params = FecParams {
                fec,
                k: u16::from_le_bytes(row[16..18].try_into().expect("2 bytes")),
                parity: u16::from_le_bytes(row[18..20].try_into().expect("2 bytes")),
                symbol_bytes: u32::from_le_bytes(row[20..24].try_into().expect("4 bytes")),
            };
            params.validate().map_err(ScrubError::Fec)?;
            blocks.push(ParityBlock {
                plan: BlockPlan {
                    block: u32::from_le_bytes(row[0..4].try_into().expect("4 bytes")),
                    offset: u64::from_le_bytes(row[4..12].try_into().expect("8 bytes")),
                    len: u32::from_le_bytes(row[12..16].try_into().expect("4 bytes")),
                    params,
                },
                source_crcs: Vec::new(),
                parity: Vec::new(),
            });
        }
        let mut pos = table_end;
        for b in &mut blocks {
            let (k, p, sym) = (
                b.plan.params.k as usize,
                b.plan.params.parity as usize,
                b.plan.params.symbol_bytes as usize,
            );
            need(pos + k * 4 + p * sym + 4)?;
            b.source_crcs = (0..k)
                .map(|j| {
                    let c: [u8; 4] = body[pos + j * 4..pos + (j + 1) * 4]
                        .try_into()
                        .expect("4 bytes");
                    u32::from_le_bytes(c)
                })
                .collect();
            pos += k * 4;
            b.parity = (0..p)
                .map(|j| body[pos + j * sym..pos + (j + 1) * sym].to_vec())
                .collect();
            pos += p * sym;
        }
        if pos != body.len() {
            return Err(ScrubError::Stale("trailing bytes after parity".into()));
        }
        Ok(Self {
            shard,
            fec,
            shard_len,
            shard_crc,
            blocks,
        })
    }

    /// Repair `shard` in place given `bad` byte ranges (any granularity —
    /// they only select which blocks to examine; inside a block, erasures
    /// are localized by re-hashing source symbols against the sidecar's
    /// per-symbol CRCs, so a whole-record damage report costs only the
    /// symbols that actually changed). Returns the indices of blocks that
    /// were reconstructed. Blocks whose erasures exceed their parity
    /// budget are reported in the error *after* every recoverable block
    /// has still been repaired — partial repair beats none.
    pub fn repair(
        &self,
        shard: &mut [u8],
        bad: &[Range<u64>],
    ) -> Result<Vec<u32>, (Vec<u32>, Vec<ScrubError>)> {
        let mut repaired = Vec::new();
        let mut failures = Vec::new();
        for b in &self.blocks {
            let range = b.byte_range();
            let touched = bad.iter().any(|r| r.start < range.end && range.start < r.end);
            if !touched {
                continue;
            }
            match repair_block(b, shard) {
                Ok(()) => repaired.push(b.plan.block),
                Err(e) => failures.push(e),
            }
        }
        if failures.is_empty() {
            Ok(repaired)
        } else {
            Err((repaired, failures))
        }
    }
}

/// Reconstruct one block: symbolize the (corrupt) shard bytes, erase
/// every symbol whose CRC deviates from the sidecar's recorded pristine
/// CRC, append the sidecar's parity, run the registry codec's `recover`,
/// and splice the first `len` bytes of the recovered source symbols back
/// over the block.
fn repair_block(block: &ParityBlock, shard: &mut [u8]) -> Result<(), ScrubError> {
    let params = &block.plan.params;
    let (k, sym) = (params.k as usize, params.symbol_bytes as usize);
    let off = block.plan.offset as usize;
    let len = block.plan.len as usize;
    if off + len > shard.len() {
        return Err(ScrubError::Stale(format!(
            "block {} [{off}, {}) past shard end {}",
            block.plan.block,
            off + len,
            shard.len()
        )));
    }
    if block.parity.len() != params.parity as usize {
        return Err(ScrubError::BadGeometry("parity symbol count".into()));
    }
    if block.source_crcs.len() != k {
        return Err(ScrubError::BadGeometry("source CRC count".into()));
    }
    let codec = fec_for(params.fec.as_u8()).ok_or(DistError::UnknownFec(params.fec.as_u8()))?;
    let source = symbolize(&shard[off..off + len], params);
    let mut symbols: Vec<Option<Vec<u8>>> = Vec::with_capacity(params.n());
    let mut erased = 0usize;
    for (i, s) in source.into_iter().enumerate() {
        if crc32(&s) != block.source_crcs[i] {
            erased += 1;
            symbols.push(None);
        } else {
            symbols.push(Some(s));
        }
    }
    for p in &block.parity {
        symbols.push(Some(p.clone()));
    }
    if erased > params.parity as usize {
        return Err(ScrubError::Unrecoverable {
            block: block.plan.block,
            have: params.n() - erased,
            need: k,
        });
    }
    codec
        .recover(params, &mut symbols)
        .map_err(ScrubError::Fec)?;
    for (i, s) in symbols[..k].iter().enumerate() {
        let s = s.as_ref().expect("recover fills every source slot");
        let lo = off + i * sym;
        let hi = (off + (i + 1) * sym).min(off + len);
        shard[lo..hi].copy_from_slice(&s[..hi - lo]);
    }
    Ok(())
}

/// Read `<dir>/shard-NNNN.ecf8p`; `Ok(None)` when the store was packed
/// without `--parity`.
pub fn load_sidecar(dir: &Path, shard: u32) -> Result<Option<ParitySidecar>, ScrubError> {
    let path = dir.join(parity_file_name(shard));
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(ScrubError::Io(format!("{}: {e}", path.display()))),
    };
    let sc = ParitySidecar::deserialize(&bytes)?;
    if sc.shard as u32 != shard {
        return Err(ScrubError::Stale(format!(
            "sidecar claims shard {}, expected {shard}",
            sc.shard
        )));
    }
    Ok(Some(sc))
}

/// Commit a sidecar tmp+rename, the same crash-safe discipline as shard
/// writes: readers only ever see a complete, CRC-trailed file.
pub fn write_sidecar(dir: &Path, sidecar: &ParitySidecar) -> Result<PathBuf, ScrubError> {
    let final_path = dir.join(parity_file_name(sidecar.shard as u32));
    let tmp = dir.join(format!("{}.tmp", parity_file_name(sidecar.shard as u32)));
    let io = |e: std::io::Error, what: &str| ScrubError::Io(format!("{what}: {e}"));
    std::fs::write(&tmp, sidecar.serialize()).map_err(|e| io(e, "writing sidecar tmp"))?;
    // unlink-then-rename: a reader holding the old mapping keeps its inode
    let _ = std::fs::remove_file(&final_path);
    std::fs::rename(&tmp, &final_path).map_err(|e| io(e, "committing sidecar"))?;
    Ok(final_path)
}

/// What [`protect_store`] wrote.
#[derive(Debug, Clone, Copy, Default)]
pub struct ProtectReport {
    pub shards: usize,
    pub blocks: usize,
    /// shard bytes covered
    pub source_bytes: u64,
    /// parity payload bytes written
    pub parity_bytes: u64,
}

/// Write a parity sidecar for every shard of a packed v2 store. Idempotent:
/// re-protecting replaces the sidecars (tmp+rename), so it is also how a
/// store's parity budget is re-tuned in place.
pub fn protect_store(dir: &Path, cfg: &SenderConfig) -> Result<ProtectReport, ScrubError> {
    let index_bytes = std::fs::read(dir.join(container::INDEX_FILE))
        .map_err(|e| ScrubError::Io(format!("reading index: {e}")))?;
    let index =
        container::TensorIndex::deserialize(&index_bytes).map_err(|e| ScrubError::Io(e.to_string()))?;
    let mut report = ProtectReport::default();
    for s in 0..index.n_shards {
        let path = dir.join(container::shard_file_name(s));
        let data =
            std::fs::read(&path).map_err(|e| ScrubError::Io(format!("{}: {e}", path.display())))?;
        let sidecar = ParitySidecar::build(s as u16, &data, cfg)?;
        report.shards += 1;
        report.blocks += sidecar.blocks.len();
        report.source_bytes += data.len() as u64;
        report.parity_bytes += sidecar.parity_bytes();
        write_sidecar(dir, &sidecar)?;
    }
    Ok(report)
}

/// Index-driven bad-range discovery for one shard: the shard header plus
/// every index entry re-verified against `bytes`. Unlike `walk_shard`
/// (which stops at the first bad record) this attributes *every* corrupt
/// range, because the index is independently CRC-protected and knows
/// each record's exact offset and length.
pub fn bad_ranges(
    index: &container::TensorIndex,
    shard: u32,
    bytes: &[u8],
) -> Vec<(Option<String>, Range<u64>)> {
    let mut bad = Vec::new();
    let header_ok = matches!(container::parse_shard_header(bytes), Ok(claimed) if claimed as u32 == shard);
    if !header_ok {
        bad.push((None, 0..SHARD_HEADER_BYTES as u64));
    }
    for e in index.entries.iter().filter(|e| e.shard == shard) {
        if verify_entry(bytes, e).is_err() {
            bad.push((Some(e.name.clone()), e.offset..e.offset + e.len));
        }
    }
    bad
}

/// Re-verify one index entry against shard bytes: bounds, header parse,
/// length, index-vs-header CRC agreement, and the payload CRC itself.
pub fn verify_entry(shard: &[u8], e: &container::IndexEntry) -> Result<(), String> {
    let off = usize::try_from(e.offset).map_err(|_| "offset overflows usize".to_string())?;
    let len = usize::try_from(e.len).map_err(|_| "length overflows usize".to_string())?;
    let end = off.checked_add(len).ok_or("offset + length overflows")?;
    if end > shard.len() {
        return Err(format!("record [{off}, {end}) past shard end {}", shard.len()));
    }
    let record = &shard[off..end];
    let header = RecordHeader::parse(record).map_err(|e| format!("header: {e}"))?;
    if header.record_len() != e.len {
        return Err(format!(
            "length mismatch: header says {}, index says {}",
            header.record_len(),
            e.len
        ));
    }
    if header.payload_crc != e.payload_crc {
        return Err(format!(
            "header/index CRC disagree ({:#010x} vs {:#010x})",
            header.payload_crc, e.payload_crc
        ));
    }
    let payload = &record[container::RECORD_HEADER_BYTES..];
    let computed = crc32(payload);
    if computed != header.payload_crc {
        return Err(format!(
            "payload CRC mismatch (stored {:#010x}, computed {computed:#010x})",
            header.payload_crc
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distribution::sender::tests::synth_shard;

    fn cfg() -> SenderConfig {
        SenderConfig {
            block_bytes: 2048,
            symbol_bytes: 256,
            parity_ratio: 0.25,
            ..SenderConfig::default()
        }
    }

    #[test]
    fn sidecar_roundtrips_bytes() {
        let shard = synth_shard(3, 9, 700, 42);
        let sc = ParitySidecar::build(3, &shard, &cfg()).unwrap();
        let bytes = sc.serialize();
        let back = ParitySidecar::deserialize(&bytes).unwrap();
        assert_eq!(back.shard, 3);
        assert_eq!(back.shard_len, shard.len() as u64);
        assert_eq!(back.shard_crc, crc32(&shard));
        assert_eq!(back.blocks.len(), sc.blocks.len());
        for (a, b) in back.blocks.iter().zip(&sc.blocks) {
            assert_eq!(a.plan, b.plan);
            assert_eq!(a.source_crcs, b.source_crcs);
            assert_eq!(a.parity, b.parity);
        }
        assert_eq!(back.serialize(), bytes);
    }

    #[test]
    fn sidecar_detects_its_own_corruption() {
        let shard = synth_shard(0, 4, 300, 7);
        let mut bytes = ParitySidecar::build(0, &shard, &cfg()).unwrap().serialize();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        match ParitySidecar::deserialize(&bytes) {
            Err(ScrubError::CrcMismatch { .. }) => {}
            other => panic!("expected CrcMismatch, got {other:?}"),
        }
    }

    #[test]
    fn single_erasure_repairs_to_byte_identity() {
        let pristine = synth_shard(1, 12, 900, 5);
        let sc = ParitySidecar::build(1, &pristine, &cfg()).unwrap();
        let mut corrupt = pristine.clone();
        // flip a byte inside some record payload in the middle of the file
        let at = corrupt.len() / 2;
        corrupt[at] ^= 0x80;
        let repaired = sc
            .repair(&mut corrupt, &[at as u64..at as u64 + 1])
            .unwrap();
        assert_eq!(repaired.len(), 1);
        assert_eq!(corrupt, pristine, "repair must restore exact bytes");
        assert_eq!(crc32(&corrupt), sc.shard_crc);
    }

    #[test]
    fn beyond_budget_is_structured_not_silent() {
        let pristine = synth_shard(2, 10, 800, 9);
        let sc = ParitySidecar::build(2, &pristine, &cfg()).unwrap();
        let b = &sc.blocks[0];
        let sym = b.plan.params.symbol_bytes as u64;
        let budget = b.plan.params.parity as u64;
        // erase parity+1 whole symbols of block 0
        let mut corrupt = pristine.clone();
        let mut bad = Vec::new();
        for i in 0..=budget {
            let lo = b.plan.offset + i * sym;
            bad.push(lo..lo + sym);
            corrupt[lo as usize] ^= 0xFF;
        }
        let err = sc.repair(&mut corrupt, &bad).unwrap_err();
        let (repaired, failures) = err;
        assert!(repaired.is_empty());
        assert!(matches!(failures[0], ScrubError::Unrecoverable { .. }));
    }

    #[test]
    fn header_bit_flip_is_repairable() {
        let pristine = synth_shard(4, 6, 500, 11);
        let sc = ParitySidecar::build(4, &pristine, &cfg()).unwrap();
        let mut corrupt = pristine.clone();
        corrupt[1] ^= 0x10; // inside the "ECS8" magic
        sc.repair(&mut corrupt, &[0..SHARD_HEADER_BYTES as u64])
            .unwrap();
        assert_eq!(corrupt, pristine);
    }

    #[test]
    fn whole_record_bad_range_narrows_to_corrupt_symbols() {
        // The index can only say "this whole record is bad", and a
        // record typically spans more symbols than a block's parity
        // budget — range-widened erasure would be beyond budget for a
        // single flipped bit. The per-symbol CRCs must narrow it.
        let pristine = synth_shard(5, 3, 900, 13);
        let sc = ParitySidecar::build(5, &pristine, &cfg()).unwrap();
        let b = &sc.blocks[0];
        let record_symbols = 932usize.div_ceil(b.plan.params.symbol_bytes as usize);
        assert!(
            record_symbols > b.plan.params.parity as usize,
            "fixture must make naive widening exceed the budget"
        );
        let mut corrupt = pristine.clone();
        // one flipped payload byte in the middle record...
        let record = (8 + 932) as u64..(8 + 2 * 932) as u64;
        corrupt[record.start as usize + 40] ^= 0x04;
        // ...reported at whole-record granularity
        let repaired = sc.repair(&mut corrupt, &[record]).unwrap();
        assert_eq!(repaired.len(), 1);
        assert_eq!(corrupt, pristine);
        assert_eq!(crc32(&corrupt), sc.shard_crc);
    }

    #[test]
    fn nocode_sidecar_is_refused() {
        let shard = synth_shard(0, 2, 100, 1);
        let cfg = SenderConfig {
            fec: FecId::NoCode,
            ..cfg()
        };
        assert!(matches!(
            ParitySidecar::build(0, &shard, &cfg),
            Err(ScrubError::BadGeometry(_))
        ));
    }

    #[test]
    fn geometry_stays_within_gf256() {
        let shard = synth_shard(0, 40, 4000, 3);
        let sc = ParitySidecar::build(0, &shard, &cfg()).unwrap();
        for b in &sc.blocks {
            assert!(b.plan.params.n() <= MAX_TOTAL_SYMBOLS);
            assert!(b.plan.params.parity >= 1);
        }
    }
}
