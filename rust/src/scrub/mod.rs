//! Self-healing model store: parity sidecars + paced CRC scrubbing +
//! index-driven local repair.
//!
//! A lossless-compression system is exactly the system that cannot
//! tolerate silent corruption: one flipped bit in a Huffman payload
//! changes model outputs undetectably unless every record's CRC is
//! actually re-checked. This module closes the loop PR 6's quarantine
//! scan opened — a packed store now *detects* (paced background CRC
//! verification), *repairs* (record-aligned Reed–Solomon parity
//! sidecars, the same GF(2⁸) codec and block planner the fleet sender
//! streams with), and *keeps serving* (tmp+rename commits that never
//! touch a mapped inode; `LazyModel`'s decode-time retry turns a
//! corrupt record under live traffic into one slow load).
//!
//! Layer map:
//! - [`parity`] — the `shard-NNNN.ecf8p` sidecar format, build/IO, and
//!   block-level erasure repair.
//! - [`scrubber`] — the [`Pacer`], the index-driven
//!   [`repair_store`]/[`repair_shard`] path, and the background
//!   [`Scrubber`] thread feeding
//!   [`ScrubMetrics`](crate::coordinator::metrics::ScrubMetrics).

pub mod parity;
pub mod scrubber;

pub use parity::{
    load_sidecar, parity_file_name, protect_store, write_sidecar, ParityBlock, ParitySidecar,
    ProtectReport, ScrubError, PARITY_MAGIC, PARITY_VERSION,
};
pub use scrubber::{
    repair_shard, repair_store, scrub_pass, Pacer, RepairedRecord, ScrubConfig, ScrubPassReport,
    Scrubber, ShardRepair, StopFlag, StoreRepairOutcome,
};
